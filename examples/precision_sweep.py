"""Layer-wise precision sweep — the paper's flexibility argument.

bitSMM's case against binarized NNs (§I) is that bit-serial hardware
lets *different layers run at different precisions*. This example
reproduces that argument end-to-end in the framework:

1. Uniform sweep w/a in {16, 8, 6, 4, 2, 1}: quality degrades gracefully
   while the analytic serial-cycle cost (Eq. 8) falls linearly with bits
   — the precision <-> latency dial.
2. Mixed policy: sensitive layers (first/last block, LM head) at 8 bits,
   the rest at 4 — the per-layer dial recovering most of the uniform-8
   quality at near-uniform-4 cost.
3. The *runtime* dial (plan API): quantize + decompose ONCE at 8 bits,
   then run the same weight tree at 8/6/4 via
   ``policy.with_runtime_bits`` — the execution plans
   (:mod:`repro.core.plan`) consume only the top planes of the stored
   decomposition (MSB-prefix truncation, zero re-quantization), exactly
   the accelerator's effective-width register.

Quality metric: KL(dense || quantized) of next-token distributions on
random prompts (random-init weights; the *relative* ordering is what the
example demonstrates).

Run:  PYTHONPATH=src python examples/precision_sweep.py [--arch granite-3-8b]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.core import plan as plan_mod
from repro.core.precision import PrecisionPolicy
from repro.core.systolic import SAConfig, matmul_total_cycles
from repro.launch.inputs import make_batch
from repro.models import forward, init_params
from repro.models.quant import quantize_params


def kl_from_dense(cfg, params, batch, dense_logits, policy):
    logits, _, _ = forward(cfg, params, batch, policy=policy)
    p = jax.nn.log_softmax(dense_logits[:, -1, : cfg.vocab_size].astype(jnp.float32))
    q = jax.nn.log_softmax(logits[:, -1, : cfg.vocab_size].astype(jnp.float32))
    return float(jnp.mean(jnp.sum(jnp.exp(p) * (p - q), axis=-1)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="granite-3-8b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    # unroll layers so per-layer-index overrides are addressable by name,
    # and deepen to 4 layers so "ends at 8, middle at 4" is non-degenerate
    import dataclasses
    cfg = dataclasses.replace(cfg, scan_layers=False, n_layers=max(cfg.n_layers, 4))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 64, "prefill", np.random.default_rng(7))
    dense, _, _ = forward(cfg, params, batch)

    sa = SAConfig(width=64, height=16)  # the paper's largest array
    n = 512  # nominal dot-product length for the cycle model

    print(f"[sweep] {cfg.name} (reduced, unrolled): KL(dense||quant) vs bits")
    print(f"  {'policy':24s} {'KL':>9s}   {'serial cycles (Eq.8+readout)':>30s}")
    for bits in (16, 8, 6, 4, 2, 1):
        pol = PrecisionPolicy.uniform(bits, bits, keep_dense=("frontend", "router"))
        kl = kl_from_dense(cfg, params, batch, dense, pol)
        cyc = matmul_total_cycles(sa, n, bits)
        print(f"  uniform w{bits:<2d}a{bits:<13d} {kl:9.4f}   {cyc:>18,d}")

    # Mixed policy: 8-bit where it hurts, 4-bit elsewhere.
    last = cfg.n_layers - 1
    mixed = PrecisionPolicy.from_dict({
        "": (4, 4),
        r"layers/0/": (8, 8),
        rf"layers/{last}/": (8, 8),
        "lm_head": (8, 8),
        "frontend|router": (None, None),
    })
    kl = kl_from_dense(cfg, params, batch, dense, mixed)
    # cost: 2 of n_layers' blocks at 8 bits, rest at 4
    c8, c4 = matmul_total_cycles(sa, n, 8), matmul_total_cycles(sa, n, 4)
    avg = (2 * c8 + (cfg.n_layers - 2) * c4) / cfg.n_layers
    print(f"  {'mixed 8/4 (ends at 8)':24s} {kl:9.4f}   {int(avg):>18,d}")
    print("[sweep] the mixed policy sits between uniform-4 cost and "
          "uniform-8 quality — the paper's layer-wise dial.")

    # 3. Runtime dial: one 8-bit decomposition, executed at 8/6/4 by
    # plane-prefix truncation (no re-quantization between rows).
    base = PrecisionPolicy.uniform(
        8, 8, variant="booth", level="bitplane",
        keep_dense=("frontend", "router"),
    )
    q_params = quantize_params(params, base, plane_cache=True)
    print("[sweep] runtime dial: ONE stored 8-bit decomposition, truncated")
    print(f"  {'runtime bits':24s} {'KL':>9s}")
    for bits in (8, 6, 4):
        pol = base.with_runtime_bits(bits, bits)
        kl = kl_from_dense(cfg, q_params, batch, dense, pol)
        print(f"  w{bits} (truncated from 8){'':4s} {kl:9.4f}")
    # what the registry resolved the dialed matmuls to
    truncated = [p for p in plan_mod.DEFAULT_REGISTRY.plans() if p.w_shift]
    if truncated:
        print("[sweep] example truncated plan:", truncated[0].describe())
    print(f"[sweep] plan registry: {len(plan_mod.DEFAULT_REGISTRY)} plans, "
          f"{plan_mod.DEFAULT_REGISTRY.hits} hits / "
          f"{plan_mod.DEFAULT_REGISTRY.misses} misses")


if __name__ == "__main__":
    main()
