"""Quickstart: the bitSMM technique in five minutes.

1. Exact bit-serial matmul (both MAC variants, all execution levels)
2. The cycle-accurate serial-MAC simulator (the paper's hardware, bit for bit)
3. The systolic-array throughput model (paper Eq. 9/10 — Fig. 6 numbers)
4. A quantized forward pass through a reduced llama-family model

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitserial import bitserial_matmul
from repro.core.quantize import quantize
from repro.core.systolic import SAConfig, gops, peak_op_per_cycle, serial_mac_dot


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


# ---------------------------------------------------------------------------
section("1. Exact bit-serial matmul")
rng = np.random.default_rng(0)
bits = 7
lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
a = jnp.asarray(rng.integers(lo, hi + 1, (8, 32)), jnp.int32)
w = jnp.asarray(rng.integers(lo, hi + 1, (32, 16)), jnp.int32)
exact = a @ w

for level in ("bitplane", "digit", "fused"):
    for variant in ("sbmwc", "booth"):
        out = bitserial_matmul(
            a, w, a_bits=bits, w_bits=bits, variant=variant, level=level
        )
        ok = bool(jnp.array_equal(out, exact))
        print(f"  level={level:9s} variant={variant:6s} exact={ok}")
        assert ok

# ---------------------------------------------------------------------------
section("2. Cycle-accurate serial MAC (the paper's hardware)")
mc = jnp.asarray(rng.integers(lo, hi + 1, (5,)), jnp.int32)
ml = jnp.asarray(rng.integers(lo, hi + 1, (5,)), jnp.int32)
for variant in ("booth", "sbmwc"):
    got, cycles = serial_mac_dot(mc, ml, bits=bits, variant=variant)
    want = int(jnp.sum(mc * ml))
    print(f"  {variant:6s}: dot={int(got):6d} (expect {want}), "
          f"cycles={cycles} (= (n+1)*b = {(5 + 1) * bits}, paper Eq. 8)")
    assert int(got) == want and cycles == (5 + 1) * bits

# ---------------------------------------------------------------------------
section("3. Systolic-array throughput model (paper Eq. 10 / Table II)")
for cols, rows in ((16, 4), (32, 8), (64, 16)):
    sa = SAConfig(width=cols, height=rows)
    g = gops(sa, bits=16, freq_hz=300e6)
    print(f"  {cols}x{rows} @300 MHz, 16-bit: peak {peak_op_per_cycle(sa, 16):6.1f} "
          f"OP/cycle -> {g:5.2f} GOPS  (paper Table II: "
          f"{ {(16, 4): 1.2, (32, 8): 4.8, (64, 16): 19.2}[(cols, rows)] })")

# ---------------------------------------------------------------------------
section("4. Quantized model forward (reduced granite-3-8b, w8a8 Booth)")
from repro.configs import get_reduced
from repro.core.precision import PrecisionPolicy
from repro.launch.inputs import make_batch
from repro.models import forward, init_params

cfg = get_reduced("granite-3-8b")
params = init_params(cfg, jax.random.PRNGKey(0))
batch = make_batch(cfg, 2, 32, "train")

dense, _, _ = forward(cfg, params, batch)
pol = PrecisionPolicy.uniform(8, 8, variant="booth", level="digit")
quant, _, _ = forward(cfg, params, batch, policy=pol)
err = float(jnp.mean(jnp.abs(dense - quant)) / (jnp.mean(jnp.abs(dense)) + 1e-9))
print(f"  logits rel-L1 error dense vs w8a8: {err:.4f} (small, != 0: quantized)")

q = quantize(jnp.asarray(rng.standard_normal((4, 8)), jnp.float32), bits=8, axis=-1)
print(f"  quantize() per-axis scales shape: {q.scale.shape}, int range "
      f"[{int(q.values.min())}, {int(q.values.max())}]")
print("\nquickstart OK")
