"""End-to-end quantized serving — the paper's own deployment scenario.

Weights are stored at the policy bit-width, activations quantize per
token at runtime, and every projection executes through the bit-serial
matmul. Serves batched requests (prefill + greedy decode) and compares
precision configurations, including the two MAC variants, which must
produce IDENTICAL tokens (both are exact integer matmuls — paper §III).

Run:  PYTHONPATH=src python examples/serve_quantized.py
          [--arch yi-6b] [--batch 4] [--prompt-len 32] [--gen 24]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.core.precision import PrecisionPolicy
from repro.launch.serve import Engine
from repro.models.transformer import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.gen + 1
    print(f"[serve] {cfg.name} (reduced), batch={args.batch}, "
          f"prompt={args.prompt_len}, gen={args.gen}")

    # Dense bf16 reference
    eng = Engine(cfg, params, PrecisionPolicy.off(), max_len=max_len)
    ref_tokens, tps = eng.generate(prompts, args.gen)
    print(f"  dense bf16          : {tps:7.1f} tok/s   tokens[0,:8]="
          f"{[int(t) for t in np.asarray(ref_tokens[0, :8])]}")

    # Quantized configs: the paper's runtime-precision dial
    results = {}
    for bits in (8, 6, 4):
        pol = PrecisionPolicy.uniform(
            bits, bits, variant="booth", level="digit",
            keep_dense=("frontend", "router"),
        )
        eng = Engine(cfg, params, pol, max_len=max_len)
        toks, tps = eng.generate(prompts, args.gen)
        agree = float(jnp.mean((toks == ref_tokens).astype(jnp.float32)))
        results[bits] = toks
        print(f"  w{bits}a{bits} booth/digit   : {tps:7.1f} tok/s   "
              f"agreement with dense: {agree:5.1%}")

    # MAC-variant equivalence: both are exact integer matmul -> same tokens
    pol_s = PrecisionPolicy.uniform(8, 8, variant="sbmwc", level="digit",
                                    keep_dense=("frontend", "router"))
    eng = Engine(cfg, params, pol_s, max_len=max_len)
    toks_s, _ = eng.generate(prompts, args.gen)
    same = bool(jnp.array_equal(toks_s, results[8]))
    print(f"  w8a8 sbmwc == booth : {same} (exactness, paper §III)")
    assert same, "MAC variants diverged — integer path broken"

    # Paper-faithful bit-plane level at low precision (b*b plane passes)
    pol_bp = PrecisionPolicy.uniform(4, 4, variant="booth", level="bitplane",
                                     keep_dense=("frontend", "router"))
    eng = Engine(cfg, params, pol_bp, max_len=max_len)
    toks_bp, tps = eng.generate(prompts, args.gen)
    same4 = bool(jnp.array_equal(toks_bp, results[4]))
    print(f"  w4a4 bitplane       : {tps:7.1f} tok/s   == digit level: {same4}")
    assert same4, "bitplane and digit levels diverged"
    print("[serve] OK")


if __name__ == "__main__":
    main()
