"""End-to-end quantized serving — the paper's own deployment scenario.

Weights are stored at the policy bit-width, activations quantize per
token at runtime, and every projection executes through a compile-once
:class:`repro.core.plan.MatmulPlan` (see DESIGN.md §7). Serves batched
requests (prefill + greedy decode) and demonstrates:

* precision as a RUNTIME knob: one engine, one 8-bit weight
  decomposition, decoded at 8/6/4 bits via ``engine.set_precision`` —
  the plans truncate the stored plane prefix, nothing is re-quantized;
* the two MAC variants producing IDENTICAL tokens (both are exact
  integer matmuls — paper §III);
* bit-plane vs digit level agreement at the same width.

Run:  PYTHONPATH=src python examples/serve_quantized.py
          [--arch yi-6b] [--batch 4] [--prompt-len 32] [--gen 24]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.core import plan as plan_mod
from repro.core.precision import PrecisionPolicy
from repro.launch.serve import Engine
from repro.models.transformer import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.gen + 1
    print(f"[serve] {cfg.name} (reduced), batch={args.batch}, "
          f"prompt={args.prompt_len}, gen={args.gen}")

    # Dense bf16 reference
    eng = Engine(cfg, params, PrecisionPolicy.off(), max_len=max_len)
    ref_tokens, tps = eng.generate(prompts, args.gen)
    print(f"  dense bf16          : {tps:7.1f} tok/s   tokens[0,:8]="
          f"{[int(t) for t in np.asarray(ref_tokens[0, :8])]}")

    # Runtime precision dial: ONE engine, ONE 8-bit decomposition. Each
    # tier is a plan swap (set_precision), not a requantization.
    pol8 = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane",
                                   keep_dense=("frontend", "router"))
    eng = Engine(cfg, params, pol8, max_len=max_len)
    results = {}
    for bits in (8, 6, 4):
        eng.set_precision(None if bits == 8 else bits)
        toks, tps = eng.generate(prompts, args.gen)
        agree = float(jnp.mean((toks == ref_tokens).astype(jnp.float32)))
        results[bits] = toks
        trunc = "stored width " if bits == 8 else "truncated    "
        print(f"  w{bits}a{bits} {trunc}  : {tps:7.1f} tok/s   "
              f"agreement with dense: {agree:5.1%}")

    # MAC-variant equivalence: both are exact integer matmul -> same tokens
    # (compared at the digit level, the TPU-native execution).
    tok_by_variant = {}
    for variant in ("booth", "sbmwc"):
        level = "digit" if variant == "booth" else "bitplane"
        pol = PrecisionPolicy.uniform(8, 8, variant=variant, level=level,
                                      keep_dense=("frontend", "router"))
        e = Engine(cfg, params, pol, max_len=max_len)
        tok_by_variant[variant], _ = e.generate(prompts, args.gen)
    same = bool(jnp.array_equal(tok_by_variant["booth"], tok_by_variant["sbmwc"]))
    print(f"  w8a8 sbmwc == booth : {same} (exactness, paper §III)")
    assert same, "MAC variants diverged — integer path broken"
    # ...and both match the bitplane engine's stored-width row above
    same8 = bool(jnp.array_equal(tok_by_variant["booth"], results[8]))
    print(f"  w8a8 digit==bitplane: {same8} (level equivalence)")
    assert same8, "bitplane and digit levels diverged"

    reg = plan_mod.DEFAULT_REGISTRY
    truncated = [p for p in reg.plans() if p.w_shift]
    print(f"[serve] plan registry: {len(reg)} plans resolved "
          f"({len(truncated)} truncated tiers), {reg.hits} hits")
    if truncated:
        print("[serve] e.g.", truncated[0].describe())
    print("[serve] OK")


if __name__ == "__main__":
    main()
