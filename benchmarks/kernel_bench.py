"""Microbenchmarks of the bit-serial matmul across execution levels,
variants and bit-widths (wall time on this host + MXU-pass accounting),
plus the quantization-error sweep behind the paper's precision dial.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitserial as bs
from repro.core.quantize import quantization_error

M, K, N = 256, 512, 256


def _time(fn, *args, iters=5, **kw) -> float:
    fn(*args, **kw).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def matmul_bench() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []
    for bits in (2, 4, 8):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        a = jnp.asarray(rng.integers(lo, hi + 1, (M, K)), jnp.int32)
        w = jnp.asarray(rng.integers(lo, hi + 1, (K, N)), jnp.int32)
        for level in ("bitplane", "digit", "fused"):
            for variant in ("booth", "sbmwc"):
                if level == "fused" and variant == "sbmwc":
                    continue
                us = _time(
                    bs.bitserial_matmul, a, w,
                    a_bits=bits, w_bits=bits, variant=variant, level=level,
                )
                passes = bs.plane_pass_count(bits, bits, level, "fully_serial")
                out.append((f"kernel/{level}_{variant}_b{bits}", round(us, 1),
                            f"mxu_passes={passes}"))
        # serial-parallel (Stripes-style) point
        us = _time(bs.bitserial_matmul, a, w, a_bits=bits, w_bits=bits,
                   variant="booth", level="bitplane", mode="serial_parallel")
        out.append((f"kernel/bitplane_sp_b{bits}", round(us, 1),
                    f"mxu_passes={bs.plane_pass_count(bits, bits, 'bitplane', 'serial_parallel')}"))
    return out


def precision_sweep() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    out = []
    for bits in (1, 2, 4, 8, 12, 16):
        err = float(quantization_error(x, bits))
        out.append((f"precision/rms_err_b{bits}", round(err, 6), "per-tensor"))
    return out


def run() -> list[tuple[str, float, str]]:
    return matmul_bench() + precision_sweep()


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
