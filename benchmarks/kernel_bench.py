"""Microbenchmarks of the bit-serial matmul across execution levels,
variants and bit-widths (wall time on this host + MXU-pass accounting),
plus the quantization-error sweep behind the paper's precision dial.

``packed_plane_bench`` sweeps packed vs. unpacked bit-plane storage
(operand bytes moved + wall time on this host's backend) and the
decompose-once weight-plane cache; ``fused_linear_bench`` compares the
staged serving linear (plane decomposition in HBM + packed kernel + XLA
dequant) against the fully-fused kernel at prefill and decode shapes.
Both dump their sections into the machine-readable ``BENCH_kernel.json``
that tracks the perf trajectory across PRs.

CLI: ``--smoke`` runs a seconds-scale subset (CI uses it to publish the
JSON as a per-PR artifact); ``--json PATH`` overrides the output file.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

# the serving bench's tp_serving sweep (driven from this process) needs 8
# virtual CPU devices; must be set before jax initializes the backend
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes as bp
from repro.core import bitserial as bs
from repro.core.quantize import quantization_error
from repro.kernels import ops

M, K, N = 256, 512, 256

# Packed-plane sweep sizes: interpret mode is an emulator, so keep the
# shape small enough that the sweep finishes in seconds per config.
PM, PK, PN = 128, 256, 128
# Weight-cache comparison runs at a decode shape (small M): that's where
# per-call weight decomposition is the largest fraction of the matmul.
DM, DK, DN = 4, 512, 512
JSON_PATH = os.environ.get("BENCH_KERNEL_JSON", "BENCH_kernel.json")


def _write_bench_section(json_path: str, name: str, payload: dict) -> None:
    """Merge one bench's payload into the shared BENCH_kernel.json (each
    bench owns a key under "benches" so sections accumulate across PRs)."""
    doc = {}
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            doc = {}
    if "benches" not in doc:  # migrate the PR-1 single-bench schema
        doc = {"benches": ({doc["bench"]: doc} if "bench" in doc else {})}
    doc["host"] = platform.node()
    doc["jax_backend"] = jax.default_backend()
    doc["benches"][name] = payload
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def _time(fn, *args, iters=5, repeats=3, **kw) -> float:
    """Best-of-``repeats`` mean over ``iters`` calls, in us (the minimum is
    the standard jitter-robust estimator on a noisy shared host)."""
    fn(*args, **kw).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args, **kw)
        out.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6  # us


def matmul_bench() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []
    for bits in (2, 4, 8):
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        a = jnp.asarray(rng.integers(lo, hi + 1, (M, K)), jnp.int32)
        w = jnp.asarray(rng.integers(lo, hi + 1, (K, N)), jnp.int32)
        for level in ("bitplane", "digit", "fused"):
            for variant in ("booth", "sbmwc"):
                if level == "fused" and variant == "sbmwc":
                    continue
                us = _time(
                    bs.bitserial_matmul, a, w,
                    a_bits=bits, w_bits=bits, variant=variant, level=level,
                )
                passes = bs.plane_pass_count(bits, bits, level, "fully_serial")
                out.append((f"kernel/{level}_{variant}_b{bits}", round(us, 1),
                            f"mxu_passes={passes}"))
        # serial-parallel (Stripes-style) point
        us = _time(bs.bitserial_matmul, a, w, a_bits=bits, w_bits=bits,
                   variant="booth", level="bitplane", mode="serial_parallel")
        out.append((f"kernel/bitplane_sp_b{bits}", round(us, 1),
                    f"mxu_passes={bs.plane_pass_count(bits, bits, 'bitplane', 'serial_parallel')}"))
    return out


def _plane_bytes(variant: str, bits: int, m: int, k: int, n: int) -> dict:
    """Operand bytes per call for the bit-plane matmul at ``bits``×``bits``.

    Unpacked: ``bits`` int8 planes per side. Packed: 1 bit/plane value
    (binary sbmwc/unsigned) or 2 (ternary booth sign+magnitude), padded to
    whole int32 words along K.
    """
    unpacked = bits * (m * k + k * n)
    words = -(-k // bp.WORD_BITS)
    per_value_words = 2 if variant == "booth" else 1
    packed = 4 * per_value_words * bits * (m * words + words * n)
    return {
        "unpacked_operand_bytes": unpacked,
        "packed_operand_bytes": packed,
        "reduction_x": round(unpacked / packed, 2),
    }


def packed_plane_bench(json_path: str = JSON_PATH) -> list[tuple[str, float, str]]:
    """Packed vs. unpacked bit-plane matmul across the precision sweep.

    Measures, per (variant, bits): operand bytes moved (exact accounting),
    MXU passes, and wall time on this host for the Pallas kernels (TPU, or
    the interpreter on CPU — an emulator, so interpret wall times gauge
    relative cost only, not HBM-bandwidth wins) and for the jnp path with
    and without the decompose-once weight-plane cache. Dumps everything to
    ``json_path`` (BENCH_kernel.json).
    """
    on_tpu = jax.default_backend() == "tpu"
    kernel_backend = "pallas" if on_tpu else "interpret"
    tiles = dict(bm=128, bn=128, bk=512) if on_tpu else dict(bm=64, bn=64, bk=128)
    rng = np.random.default_rng(2)
    rows: list[tuple[str, float, str]] = []
    records = []
    for bits in (2, 4, 8):
        lo, hi = bp.signed_range(bits)
        a = jnp.asarray(rng.integers(lo, hi + 1, (PM, PK)), jnp.int32)
        w = jnp.asarray(rng.integers(lo, hi + 1, (PK, PN)), jnp.int32)
        ad = jnp.asarray(rng.integers(lo, hi + 1, (DM, DK)), jnp.int32)
        wd = jnp.asarray(rng.integers(lo, hi + 1, (DK, DN)), jnp.int32)
        for variant in ("sbmwc", "booth"):
            kw = dict(
                a_bits=bits, w_bits=bits, variant=variant, level="bitplane",
                backend=kernel_backend, **tiles,
            )
            us_unpacked = _time(ops.bitserial_matmul, a, w, packed=False, iters=2, **kw)
            us_packed = _time(ops.bitserial_matmul, a, w, packed=True, iters=2, **kw)
            # decompose-once weight cache, jnp path, decode shape (the
            # serving CPU win: no per-call weight-side work)
            wp = bp.make_weight_planes(wd, w_bits=bits, variant=variant, level="bitplane")
            jkw = dict(
                a_bits=bits, w_bits=bits, variant=variant, level="bitplane",
                backend="jnp",
            )
            us_jnp = _time(ops.bitserial_matmul, ad, wd, iters=8, **jkw)
            us_jnp_cached = _time(
                ops.bitserial_matmul, ad, wd, w_planes=wp, iters=8, **jkw
            )
            nbytes = _plane_bytes(variant, bits, PM, PK, PN)
            name = f"bitplane_{variant}_b{bits}"
            rows.append((
                f"kernel/packed_{name}", round(us_packed, 1),
                f"bytes_x{nbytes['reduction_x']}_vs_unpacked_{round(us_unpacked, 1)}us",
            ))
            rows.append((
                f"kernel/wcache_jnp_{name}", round(us_jnp_cached, 1),
                f"uncached_{round(us_jnp, 1)}us",
            ))
            records.append({
                "name": name,
                "level": "bitplane",
                "variant": variant,
                "a_bits": bits,
                "w_bits": bits,
                "kernel_shape": [PM, PK, PN],
                "decode_shape": [DM, DK, DN],
                "mxu_passes": bs.plane_pass_count(bits, bits, "bitplane", "fully_serial"),
                "bytes": nbytes,
                "wall_us": {
                    f"{kernel_backend}_unpacked": round(us_unpacked, 1),
                    f"{kernel_backend}_packed": round(us_packed, 1),
                    "jnp_decode_weight_decompose_per_call": round(us_jnp, 1),
                    "jnp_decode_weight_plane_cache": round(us_jnp_cached, 1),
                    "jnp_decode_cache_speedup_x": round(us_jnp / us_jnp_cached, 2),
                },
            })
    payload = {
        "bench": "packed_plane_matmul",
        "kernel_backend": kernel_backend,
        "note": (
            "bytes are exact operand-traffic accounting; interpret-mode wall "
            "times emulate the kernel op-by-op on CPU and do not reflect HBM "
            "bandwidth (the packed win is the bytes column; the measured CPU "
            "wall-clock win is the weight-plane cache column)"
        ),
        "configs": records,
    }
    _write_bench_section(json_path, "packed_plane_matmul", payload)
    return rows


# -- fused linear: staged vs fully-fused --------------------------------------


def _fused_linear_bytes(
    variant: str, a_bits: int, w_bits: int, m: int, k: int, n: int, block: int
) -> dict:
    """HBM bytes per serving linear call, staged vs fused.

    Staged (plane cache, packed kernel, XLA epilogue): the activation
    planes + packed activation words are materialized in HBM (write+read
    each), the int32 accumulator does a write + re-read for the dequant,
    and the bf16 result is written. Fused: int8 activations + packed
    weight words + scales in, bf16 out — nothing else touches HBM.
    """
    pv = 2 if variant == "booth" else 1  # ternary planes carry a sign word
    # ``block`` is the cache's actual (already clamped) pack block
    kw_words = -(-k // block) * (block // bp.WORD_BITS)
    w_packed = 4 * pv * w_bits * kw_words * n
    a_planes = a_bits * m * k  # int8 plane tensor
    a_packed = 4 * pv * a_bits * m * -(-k // bp.WORD_BITS)
    scales = 4 * (m + n) + 4 * n  # a_scale + w_scale + bias (f32 reads)
    out_bf16 = 2 * m * n
    staged = (
        m * k              # read int8 x_q
        + 2 * a_planes     # write + read decomposed activation planes
        + 2 * a_packed     # write + read packed activation words
        + w_packed         # read packed weight planes
        + 8 * m * n        # int32 accumulator write + re-read
        + scales
        + out_bf16
    )
    fused = m * k + w_packed + scales + out_bf16
    return {
        "staged_hbm_bytes": staged,
        "fused_hbm_bytes": fused,
        "reduction_x": round(staged / fused, 2),
    }


def fused_linear_bench(
    json_path: str = JSON_PATH, smoke: bool = False
) -> list[tuple[str, float, str]]:
    """Staged vs fully-fused serving linear at prefill and decode shapes.

    Wall time on this host's kernel backend (pallas on TPU; the interpret
    emulator elsewhere — relative cost only) plus the exact HBM-byte
    accounting that is the TPU-relevant win. Configs mirror the serving
    path: blocked plane cache, per-token/per-channel scales, bias + silu
    epilogue.
    """
    on_tpu = jax.default_backend() == "tpu"
    kernel_backend = "pallas" if on_tpu else "interpret"
    if smoke:
        shapes = {"prefill": (64, 128, 128), "decode": (8, 128, 128)}
        configs = [("booth", 4)]
    elif on_tpu:
        shapes = {"prefill": (2048, 512, 512), "decode": (8, 512, 512)}
        configs = [("booth", 4), ("sbmwc", 8)]
    else:
        shapes = {"prefill": (256, 256, 256), "decode": (8, 256, 256)}
        configs = [("booth", 4), ("sbmwc", 8)]
    rng = np.random.default_rng(3)
    rows: list[tuple[str, float, str]] = []
    records = []
    for variant, bits in configs:
        lo, hi = bp.signed_range(bits)
        for shape_name, (m, k, n) in shapes.items():
            a = jnp.asarray(rng.integers(lo, hi + 1, (m, k)), jnp.int8)
            w = jnp.asarray(rng.integers(lo, hi + 1, (k, n)), jnp.int32)
            wp = bp.make_weight_planes(w, w_bits=bits, variant=variant,
                                       level="bitplane", store="packed")
            ep = ops.Epilogue(
                a_scale=jnp.asarray(rng.uniform(0.01, 0.1, (m, 1)), jnp.float32),
                w_scale=jnp.asarray(rng.uniform(0.01, 0.1, (1, n)), jnp.float32),
                bias=jnp.asarray(rng.standard_normal(n), jnp.float32),
                activation="silu",
            )
            kw = dict(
                a_bits=bits, w_bits=bits, variant=variant, level="bitplane",
                backend=kernel_backend, w_planes=wp, epilogue=ep, packed=True,
            )
            # Smoke shapes are small enough for real repetition — their
            # staged/fused ratio feeds the hard-failing CI regression gate,
            # so it must not rest on single-iteration timings. The full
            # sweep's larger shapes stay at best-of-2 singles.
            t_kw = dict(iters=3, repeats=3) if smoke else dict(iters=1, repeats=2)
            us_staged = _time(ops.bitserial_matmul, a, w, fused=False, **t_kw, **kw)
            us_fused = _time(ops.bitserial_matmul, a, w, fused=True, **t_kw, **kw)
            nbytes = _fused_linear_bytes(
                variant, bits, bits, m, k, n, wp.packed.block
            )
            name = f"{shape_name}_{variant}_b{bits}"
            rows.append((
                f"kernel/fused_{name}", round(us_fused, 1),
                f"bytes_x{nbytes['reduction_x']}_vs_staged_{round(us_staged, 1)}us",
            ))
            records.append({
                "name": name,
                "shape": [m, k, n],
                "variant": variant,
                "a_bits": bits,
                "w_bits": bits,
                "pack_block": wp.packed.block,
                "mxu_passes": bs.plane_pass_count(bits, bits, "bitplane", "fully_serial"),
                "bytes": nbytes,
                "wall_us": {
                    f"{kernel_backend}_staged": round(us_staged, 1),
                    f"{kernel_backend}_fused": round(us_fused, 1),
                },
            })
    payload = {
        "bench": "fused_linear",
        "kernel_backend": kernel_backend,
        "smoke": smoke,
        "note": (
            "staged = plane decomposition + packed kernel + XLA dequant "
            "epilogue (int32 accumulator round-trips HBM); fused = one "
            "launch, in-kernel activation bit-slicing + epilogue, bf16 out. "
            "bytes are exact HBM-traffic accounting; interpret wall times "
            "emulate the kernels on CPU and do not see HBM bandwidth"
        ),
        "configs": records,
    }
    # Smoke mode writes its own section: smoke shapes differ from the full
    # sweep's, and the CI regression gate compares speedups shape-for-shape
    # against the committed baseline.
    _write_bench_section(
        json_path, "fused_linear_smoke" if smoke else "fused_linear", payload
    )
    return rows


def precision_sweep() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    out = []
    for bits in (1, 2, 4, 8, 12, 16):
        err = float(quantization_error(x, bits))
        out.append((f"precision/rms_err_b{bits}", round(err, 6), "per-tensor"))
    return out


def run(json_path: str | None = None, smoke: bool = False) -> list[tuple[str, float, str]]:
    from serving_bench import serving_bench

    path = json_path or JSON_PATH
    if smoke:
        # CI-scale subset: the fused-vs-staged comparison and the serving
        # parity/KV-byte section are the per-PR regression signals;
        # everything else runs in the full sweep.
        return fused_linear_bench(path, smoke=True) + serving_bench(path, smoke=True)
    return (
        matmul_bench()
        + packed_plane_bench(path)
        + fused_linear_bench(path)
        + serving_bench(path)
        + precision_sweep()
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale subset (CI artifact mode)")
    ap.add_argument("--json", default=None, help="output JSON path")
    args = ap.parse_args()
    for name, val, derived in run(args.json, smoke=args.smoke):
        print(f"{name},{val},{derived}")
