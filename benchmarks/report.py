"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
records.

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun_final]

Prints markdown; EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import argparse
import json
import pathlib

ARCH_ORDER = [
    "llama3-405b", "deepseek-coder-33b", "granite-3-8b", "yi-6b",
    "mamba2-1.3b", "qwen3-moe-235b-a22b", "llama4-scout-17b-a16e",
    "recurrentgemma-2b", "hubert-xlarge", "internvl2-2b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str):
    recs = {}
    for f in pathlib.Path(dirpath).glob("*.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def dryrun_table(recs, mesh: str) -> str:
    rows = [
        "| arch | shape | status | GiB/dev (raw) | GiB/dev (TPU) | fits 16G | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "SKIP":
                rows.append(f"| {arch} | {shape} | SKIP — {r['reason']} | | | | |")
                continue
            if r["status"] == "FAIL":
                rows.append(f"| {arch} | {shape} | **FAIL** | | | | |")
                continue
            m = r["memory"]
            tpu = m.get("per_device_bytes_tpu", m["per_device_bytes"])
            rows.append(
                f"| {arch} | {shape} | OK | {fmt_bytes(m['per_device_bytes'])} "
                f"| {fmt_bytes(tpu)} | {'yes' if m['fits_16gb'] else 'NO'} "
                f"| {r['compile_s']} |"
            )
    return "\n".join(rows)


def roofline_table(recs, mesh: str) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| MODEL/HLO flops | MFU@roofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None or r["status"] != "OK":
                continue
            rl = r["roofline"]
            rows.append(
                f"| {arch} | {shape} | {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
                f"| {rl['collective_s']:.3f} | {rl['bottleneck']} "
                f"| {rl['useful_flop_fraction']:.3f} | {rl['mfu_at_roofline']:.4f} |"
            )
    return "\n".join(rows)


def summary(recs) -> str:
    by = {"OK": 0, "SKIP": 0, "FAIL": 0}
    fits = 0
    ok = 0
    for r in recs.values():
        by[r["status"]] += 1
        if r["status"] == "OK":
            ok += 1
            if r["memory"]["fits_16gb"]:
                fits += 1
    return (
        f"{len(recs)} cells: {by['OK']} OK, {by['SKIP']} documented skips, "
        f"{by['FAIL']} failures; {fits}/{ok} compiled cells fit 16 GiB/chip "
        f"(TPU-corrected occupancy)."
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun_final")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print(f"no records in {args.dir}")
        return
    print("## Summary\n")
    print(summary(recs))
    for mesh in ("16x16", "2x16x16"):
        if not any(k[2] == mesh for k in recs):
            continue
        print(f"\n## Dry-run — mesh {mesh}\n")
        print(dryrun_table(recs, mesh))
    print("\n## Roofline — single pod (16x16, 256 chips)\n")
    print(roofline_table(recs, "16x16"))


if __name__ == "__main__":
    main()
