"""End-to-end system benches: tiny-config train step throughput and
quantized serve decode throughput (host wall-time)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.precision import PrecisionPolicy
from repro.launch.inputs import make_batch
from repro.launch.serve import Engine
from repro.launch.steps import init_opt_state, make_train_step
from repro.models import init_params
from repro.optim import OptimConfig


def train_bench(arch="granite-3-8b", steps=5):
    cfg = get_reduced(arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(total_steps=steps)
    opt_state = init_opt_state(cfg, opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    batch = make_batch(cfg, 8, 128, "train", rng)
    params, opt_state, m = step(params, opt_state, batch, jnp.int32(0))  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(steps):
        params, opt_state, m = step(params, opt_state, batch, jnp.int32(i + 1))
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    toks = 8 * 128 / dt
    return dt * 1e6, f"tokens_per_s={toks:.0f};loss={float(m['loss']):.3f}"


def serve_bench(arch="yi-6b", bits=8):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pol = PrecisionPolicy.uniform(bits, bits) if bits else PrecisionPolicy.off()
    engine = Engine(cfg, params, pol, max_len=64)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    engine.generate(prompts, 4)  # warm
    t0 = time.perf_counter()
    _, tps = engine.generate(prompts, 16)
    dt = time.perf_counter() - t0
    return dt / 16 * 1e6, f"decode_tok_per_s={tps:.0f}"


def run():
    out = []
    us, d = train_bench()
    out.append(("e2e/train_step_granite_reduced", round(us, 0), d))
    for bits in (0, 8, 4):
        us, d = serve_bench(bits=bits)
        tag = f"w{bits}a{bits}" if bits else "bf16"
        out.append((f"e2e/serve_decode_yi_{tag}", round(us, 0), d))
    return out


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
