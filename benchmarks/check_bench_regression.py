"""CI bench regression gate.

Compares a freshly generated ``--smoke`` BENCH_kernel.json against the
committed baseline and fails (exit 1) when:

* the fused-vs-staged speedup of any config present in both files
  regresses by more than ``--threshold`` (default 15%) — speedups are
  wall-time *ratios* on the same host/run, so they transfer across
  machines far better than absolute microseconds;
* any ``parity`` entry in the fresh file reports something other than
  ``"ok"`` — bit-exactness (continuous batching vs lockstep, int8-KV
  first tokens) and the no-requantization invariant of the runtime
  precision sweep are hard invariants, not tolerances;
* the serving ``precision_sweep`` (decode tok/s at 4-bit vs 8-bit from
  one stored decomposition) falls below ``--sweep-floor`` — plane-prefix
  truncation does 1/4 the plane-pair work at 4-bit, so the ratio
  collapsing toward 1x means the dial silently stopped truncating;
* the ``sparsity_sweep`` compact-vs-dense decode ratio on the
  narrow-checkpoint tier falls below ``--sparsity-floor`` — occupancy
  compaction drops half the weight planes there, so the ratio collapsing
  toward 1x means pack-time plane compaction silently stopped shrinking
  the plane-pair grid. Its parity entries (gated/compacted tokens must
  equal dense bit for bit) hard-fail like every other parity verdict;
* the ``integrity`` section's detect-vs-off decode overhead exceeds
  ``--integrity-ceiling`` (default 1.15x) — the ABFT + audit layer must
  stay cheap enough to leave on in production. Its parity entries (100%
  injected-fault detection, bit-identical scrub recovery, detect==off
  tokens) hard-fail like every other parity verdict;
* the ``tp_serving`` section's per-device plane-cache bytes stop
  shrinking with model parallelism: at model_parallel = P the footprint
  must stay within ``--tp-shrink-slack`` (default 1.25x) of 1/P of the
  single-device footprint — the whole point of sharding the weight-plane
  caches is that each device holds ~its slice. A missing or skipped
  section fails (the bench runs on 8 virtual CPU devices via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, which
  kernel_bench.py sets by default). Its parity entries (sharded tokens
  vs the single-device oracle) hard-fail like every other parity
  verdict;
* the ``paged_serving`` section's resident-KV shrink falls below
  ``--kv-shrink-floor`` (default 1.2x): at 80% shared prefixes under
  slot churn, the paged engine's peak resident page bytes must sit
  below the dense engine's always-resident cache — the ratio collapsing
  toward 1x means paged allocation or CoW prefix sharing silently
  stopped saving memory. A missing or skipped section fails loudly, and
  its token-parity verdicts (paged chunked AND monolithic vs the dense
  oracle, bit for bit) hard-fail like every other parity entry;
* the ``autopilot`` section's overload ramp stops holding its SLA: the
  autopilot run's p99 queue steps must be within ``sla_queue_steps``
  while the static 8-bit baseline exceeds it (a ramp the static engine
  survives makes the verdict vacuous and fails too). Its parity entries
  (never-degraded tokens == static run, degraded tokens == single-tier
  run of the admission tier, shedding only at the lowest tier) hard-fail
  like every other parity verdict.

Input handling is itself gated: a missing file, malformed JSON, a
document without a ``benches`` section, and a non-finite (NaN/inf)
metric each fail with a distinct, actionable message instead of a
traceback — CI artifacts go missing or get torn often enough that
"which of the five ways did it break" should not require reading a
stack trace.

Sections are matched by (bench section, config name, shape): the smoke
sweep writes ``fused_linear_smoke`` so CI compares smoke shapes against
committed smoke shapes, never against the full sweep's larger shapes.

Usage:
    python benchmarks/check_bench_regression.py \
        --fresh BENCH_fresh.json --baseline BENCH_kernel.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys


def _load(path: str, label: str) -> tuple[dict | None, list[str]]:
    """Load one bench report; every way the input can be broken gets its
    own actionable failure instead of a traceback."""
    try:
        with open(path) as f:
            text = f.read()
    except FileNotFoundError:
        return None, [
            f"{label} file {path!r} does not exist — for the baseline, "
            "regenerate and commit it (python benchmarks/kernel_bench.py "
            "--smoke); for the fresh file, the bench step upstream of the "
            "gate did not run or wrote elsewhere"
        ]
    except OSError as e:
        return None, [f"{label} file {path!r} is unreadable: {e}"]
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        return None, [
            f"{label} file {path!r} is not valid JSON (line {e.lineno} "
            f"col {e.colno}: {e.msg}) — usually a truncated or torn "
            "write; regenerate the report"
        ]
    if not isinstance(doc, dict) or not isinstance(doc.get("benches"), dict) \
            or not doc["benches"]:
        return None, [
            f"{label} file {path!r} has no 'benches' section — it is not "
            "a kernel-bench report; point the gate at BENCH_kernel.json-"
            "style files"
        ]
    return doc, []


def _nan_failures(doc: dict, label: str) -> list[str]:
    """A NaN/inf metric means a bench divided by zero or timed nothing —
    every ratio comparison downstream would silently pass or fail on it."""
    fails: list[str] = []

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}.{k}")
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}[{i}]")
        elif isinstance(node, float) and not math.isfinite(node):
            fails.append(
                f"{label} metric {path} is {node!r} — a bench produced a "
                "non-finite number (zero wall time or 0/0 ratio); the run "
                "is unusable, regenerate it"
            )

    walk(doc.get("benches", {}), "benches")
    return fails


def _fused_speedups(doc: dict, section: str) -> dict[tuple, float]:
    """(name, shape) -> staged/fused wall-time speedup."""
    out: dict[tuple, float] = {}
    bench = doc.get("benches", {}).get(section)
    if not bench:
        return out
    for cfg in bench.get("configs", []):
        wall = cfg.get("wall_us", {})
        staged = fused = None
        for key, val in wall.items():
            if key.endswith("_staged"):
                staged = val
            elif key.endswith("_fused"):
                fused = val
        if staged and fused:
            out[(cfg["name"], tuple(cfg.get("shape", ())))] = staged / fused
    return out


def _floor_failures(
    sweep: dict | None,
    *,
    section: str,
    key: str,
    floor: float,
    label: str,
    missing: str,
    collapse: str,
) -> list[str]:
    """Shared floor gate for the self-contained serving sweeps: their
    ratios come from one host and one run, so they are checked against an
    absolute floor rather than a committed baseline — and a missing
    section fails loudly (mirroring the fused gate's no-overlap rule)
    instead of passing vacuously."""
    if not sweep:
        return [
            f"no {section} section in the fresh run — serving_bench "
            f"stopped emitting the {missing} the gate is supposed to "
            "floor-check"
        ]
    got = sweep.get(key, 0.0)
    verdict = "ok" if got >= floor else "REGRESSED"
    print(f"[gate] {section}: {label} {got:.2f}x (floor {floor:.2f}x) {verdict}")
    if got < floor:
        return [
            f"{section} {key} {got:.2f}x below floor {floor:.2f}x — "
            f"{collapse} is not paying for itself"
        ]
    return []


def _sweep_failures(doc: dict, floor: float) -> list[str]:
    return _floor_failures(
        doc.get("benches", {}).get("serving", {}).get("precision_sweep"),
        section="serving.precision_sweep",
        key="speedup_4_vs_8",
        floor=floor,
        label="4-bit vs 8-bit decode",
        missing="runtime-precision sweep",
        collapse="runtime truncation",
    )


def _sparsity_failures(doc: dict, floor: float) -> list[str]:
    return _floor_failures(
        doc.get("benches", {}).get("sparsity_sweep"),
        section="sparsity_sweep",
        key="speedup_compact_vs_dense_4bit",
        floor=floor,
        label="compact vs dense decode (4-bit tier)",
        missing="occupancy-sparsity sweep",
        collapse="plane compaction",
    )


def _integrity_failures(doc: dict, ceiling: float) -> list[str]:
    """Ceiling gate on the ABFT/audit serving cost. Detection and
    recovery verdicts ride the hard parity gate; this checks the one
    number that is a tolerance, not an invariant: detect-mode decode
    must stay within ``ceiling`` of unchecked decode."""
    integ = doc.get("benches", {}).get("integrity")
    if not integ:
        return [
            "no integrity section in the fresh run — serving_bench "
            "stopped emitting the ABFT/fault-injection sweep the gate is "
            "supposed to ceiling-check"
        ]
    got = integ.get("overhead_detect_vs_off_x", float("inf"))
    verdict = "ok" if got <= ceiling else "REGRESSED"
    print(
        f"[gate] integrity: detect-vs-off decode overhead {got:.3f}x "
        f"(ceiling {ceiling:.2f}x) {verdict}"
    )
    if got > ceiling:
        return [
            f"integrity overhead_detect_vs_off_x {got:.3f}x above ceiling "
            f"{ceiling:.2f}x — the ABFT + audit layer costs more than the "
            "always-on fault-tolerance budget"
        ]
    return []


def _autopilot_failures(doc: dict) -> list[str]:
    """SLA gate on the autopilot overload ramp. The tier-contract token
    parities (`undegraded_tokens_vs_static`, `degraded_tokens_vs_
    single_tier`, `shed_only_at_lowest`) ride the hard parity gate; this
    checks the closed loop's reason to exist from the raw numbers: under
    the scripted ramp the autopilot's p99 queue wait must sit within the
    configured SLA, and the static 8-bit baseline must demonstrably
    exceed it (otherwise the ramp no longer overloads anything and the
    SLA verdict is vacuous)."""
    ap = doc.get("benches", {}).get("autopilot")
    if not ap:
        return [
            "no autopilot section in the fresh run — serving_bench "
            "stopped emitting the SLA-autopilot overload ramp the gate "
            "is supposed to check"
        ]
    sla = ap.get("sla_queue_steps", 0.0)
    p99 = ap.get("p99_queue_steps", {})
    got = p99.get("autopilot", float("inf"))
    static = p99.get("static_w8", 0.0)
    verdict = "ok" if got <= sla < static else "REGRESSED"
    print(
        f"[gate] autopilot: p99 queue steps {got:.2f} (SLA {sla:.2f}, "
        f"static baseline {static:.2f}) {verdict}"
    )
    fails = []
    if got > sla:
        fails.append(
            f"autopilot p99 queue steps {got:.2f} violates the scripted "
            f"SLA {sla:.2f} — the closed loop stopped holding the latency "
            "contract it exists for"
        )
    if static <= sla:
        fails.append(
            f"static-baseline p99 queue steps {static:.2f} within the "
            f"SLA {sla:.2f} — the scripted ramp no longer overloads the "
            "static engine, so the autopilot SLA verdict is vacuous; "
            "re-tune the ramp in serving_bench.autopilot_sweep"
        )
    return fails


def _tp_serving_failures(doc: dict, slack: float) -> list[str]:
    """Footprint gate on the tensor-parallel serving sweep. Token parity
    vs the single-device oracle rides the hard parity gate; this checks
    the capacity claim: per-device plane-cache bytes at model_parallel=P
    must be within ``slack`` of base/P (pack-word padding and the few
    replicated non-TP leaves are the tolerated overhead)."""
    tp = doc.get("benches", {}).get("tp_serving")
    if not tp:
        return [
            "no tp_serving section in the fresh run — serving_bench "
            "stopped emitting the tensor-parallel sweep the gate is "
            "supposed to check"
        ]
    if "skipped" in tp:
        return [
            f"tp_serving sweep was skipped ({tp['skipped']}) — the bench "
            "leg must run with XLA_FLAGS="
            "--xla_force_host_platform_device_count=8"
        ]
    per_dev = tp.get("plane_cache_bytes_per_device", {})
    base = per_dev.get("model1", 0)
    fails = []
    for mp in tp.get("model_parallel", []):
        if mp == 1:
            continue
        got = per_dev.get(f"model{mp}", float("inf"))
        ceiling = base / mp * slack
        verdict = "ok" if got <= ceiling else "REGRESSED"
        print(
            f"[gate] tp_serving: model={mp} plane-cache bytes/device "
            f"{got} (1/P of base = {base / mp:.0f}, slack {slack:.2f}x) "
            f"{verdict}"
        )
        if got > ceiling:
            fails.append(
                f"tp_serving model={mp} plane-cache bytes/device {got} "
                f"exceeds base/{mp} * {slack:.2f} = {ceiling:.0f} — the "
                "weight-plane caches stopped sharding down with model "
                "parallelism"
            )
    return fails


def _paged_serving_failures(doc: dict, floor: float) -> list[str]:
    """Residency gate on the paged-KV serving sweep. Token parity vs the
    dense oracle (chunked and monolithic prefill) rides the hard parity
    gate; this checks the subsystem's capacity claim: at 80% shared
    prefixes under slot churn, peak resident page bytes must sit below
    the dense engine's always-resident cache by ``floor``. A missing or
    skipped section fails loudly, like every other serving sweep."""
    return _floor_failures(
        doc.get("benches", {}).get("paged_serving"),
        section="paged_serving",
        key="kv_shrink_x",
        floor=floor,
        label="dense-vs-paged resident KV bytes",
        missing="paged-KV residency sweep",
        collapse="paged allocation + CoW prefix sharing",
    )


def _tuned_failures(doc: dict, floor: float) -> list[str]:
    """Autotuner gate on the tuned_tiles sweep. Token parity across
    heuristic/tuned phases and the warm-start zero-tune verdict ride the
    hard parity gate; this checks the performance claim: tuned decode AND
    prefill throughput must each be >= ``floor`` x the auto_tiles
    heuristic on every measured workload (on the jnp bench host the
    honest expectation is ~1.0x — tiles are inert there — so the floor
    is slack for host noise, not a win target; collapse far below 1x
    means the tuner is picking actively bad tiles or the store lookup
    path got expensive). A missing or skipped section fails loudly."""
    tt = doc.get("benches", {}).get("tuned_tiles")
    if not tt:
        return [
            "no tuned_tiles section in the fresh run — serving_bench "
            "stopped emitting the autotuner sweep the gate is supposed "
            "to check"
        ]
    if "skipped" in tt:
        return [f"tuned_tiles sweep was skipped ({tt['skipped']})"]
    ratios = tt.get("tuned_vs_heuristic", {})
    if not ratios:
        return [
            "tuned_tiles section carries no tuned_vs_heuristic ratios — "
            "the sweep ran but measured nothing the gate can check"
        ]
    fails = []
    for workload, got in sorted(ratios.items()):
        verdict = "ok" if got >= floor else "REGRESSED"
        print(
            f"[gate] tuned_tiles: {workload} tuned/heuristic {got:.3f}x "
            f"(floor {floor:.2f}x) {verdict}"
        )
        if got < floor:
            fails.append(
                f"tuned_tiles {workload} tuned-vs-heuristic throughput "
                f"{got:.3f}x below floor {floor:.2f}x — autotuned plans "
                "are slower than the auto_tiles heuristic they must "
                "never lose to"
            )
    return fails


def _parity_failures(doc: dict) -> list[str]:
    fails = []
    for section, bench in doc.get("benches", {}).items():
        for check, verdict in bench.get("parity", {}).items():
            if verdict != "ok":
                fails.append(f"{section}.parity.{check} = {verdict!r}")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="just-generated smoke JSON")
    ap.add_argument("--baseline", required=True, help="committed BENCH_kernel.json")
    ap.add_argument(
        "--threshold", type=float, default=0.15,
        help="max tolerated relative speedup regression (0.15 = 15%%)",
    )
    ap.add_argument(
        "--section", default="fused_linear_smoke",
        help="bench section holding the fused-vs-staged comparison",
    )
    ap.add_argument(
        "--sweep-floor", type=float, default=1.5,
        help="min tolerated 4-bit-vs-8-bit decode speedup in the serving "
        "precision sweep (measured 3x+ on dev hosts; ratio-based so it "
        "transfers across machines)",
    )
    ap.add_argument(
        "--sparsity-floor", type=float, default=1.2,
        help="min tolerated compact-vs-dense decode speedup on the "
        "sparsity sweep's narrow-checkpoint tier (measured ~1.8x on dev "
        "hosts; compaction halves the plane-pair grid there)",
    )
    ap.add_argument(
        "--integrity-ceiling", type=float, default=1.15,
        help="max tolerated detect-vs-off decode overhead from the "
        "integrity sweep (ABFT + audits must stay within 15%% to be an "
        "always-on production mode)",
    )
    ap.add_argument(
        "--tp-shrink-slack", type=float, default=1.25,
        help="max tolerated per-device plane-cache bytes at "
        "model_parallel=P as a multiple of 1/P of the single-device "
        "footprint (pack-word padding + replicated non-TP leaves)",
    )
    ap.add_argument(
        "--tuned-floor", type=float, default=0.8,
        help="min tolerated tuned-vs-heuristic throughput ratio from the "
        "tuned_tiles sweep, per workload (expected ~1.0 on the jnp bench "
        "host where tiles are inert; the floor is slack for shared-host "
        "noise — the failure mode is the tuner selecting tiles slower "
        "than the auto_tiles default it is supposed to dominate)",
    )
    ap.add_argument(
        "--kv-shrink-floor", type=float, default=1.2,
        help="min tolerated dense/paged resident-KV-bytes ratio from the "
        "paged_serving sweep at 80%% shared prefixes (measured ~1.8x on "
        "the smoke workload; the failure mode is paged allocation or CoW "
        "sharing silently holding as many pages as dense residency)",
    )
    args = ap.parse_args(argv)

    fresh, failures = _load(args.fresh, "fresh")
    baseline, b_fails = _load(args.baseline, "baseline")
    failures.extend(b_fails)
    if fresh is None or baseline is None:
        print(f"[gate] FAILED ({len(failures)} problem(s)):")
        for f_ in failures:
            print(f"[gate]   - {f_}")
        return 1

    failures.extend(_nan_failures(fresh, "fresh"))

    base_sp = _fused_speedups(baseline, args.section)
    fresh_sp = _fused_speedups(fresh, args.section)
    compared = 0
    for key, base in sorted(base_sp.items()):
        if key not in fresh_sp:
            print(f"[gate] WARN: {key} in baseline but not in fresh run")
            continue
        got = fresh_sp[key]
        floor = base * (1.0 - args.threshold)
        verdict = "ok" if got >= floor else "REGRESSED"
        print(
            f"[gate] {args.section} {key}: speedup {got:.2f}x "
            f"(baseline {base:.2f}x, floor {floor:.2f}x) {verdict}"
        )
        compared += 1
        if got < floor:
            failures.append(
                f"{key}: fused-vs-staged speedup {got:.2f}x regressed "
                f">{args.threshold:.0%} below baseline {base:.2f}x"
            )
    if not compared:
        # a gate that silently compares nothing is worse than no gate
        failures.append(
            f"no overlapping '{args.section}' configs between fresh and "
            "baseline — regenerate the committed BENCH_kernel.json with "
            "--smoke so CI has a baseline to gate against"
        )

    failures.extend(_sweep_failures(fresh, args.sweep_floor))
    failures.extend(_sparsity_failures(fresh, args.sparsity_floor))
    failures.extend(_integrity_failures(fresh, args.integrity_ceiling))
    failures.extend(_autopilot_failures(fresh))
    failures.extend(_tp_serving_failures(fresh, args.tp_shrink_slack))
    failures.extend(_paged_serving_failures(fresh, args.kv_shrink_floor))
    failures.extend(_tuned_failures(fresh, args.tuned_floor))

    parity = _parity_failures(fresh)
    for p in parity:
        print(f"[gate] PARITY FAIL: {p}")
    failures.extend(parity)
    if not parity:
        n = sum(len(b.get("parity", {})) for b in fresh.get("benches", {}).values())
        print(f"[gate] parity: {n} checks ok")

    if failures:
        print(f"[gate] FAILED ({len(failures)} problem(s)):")
        for f_ in failures:
            print(f"[gate]   - {f_}")
        return 1
    print("[gate] PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
