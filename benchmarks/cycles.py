"""Cycle-count scaling: bitSMM Eq. 8 vs BISMO/Loom Eq. 6 (paper §III-A).

Reproduces the claim that bitSMM's (n+1)*b_max beats b_mc*b_ml*n for all
operand widths > 2 and matches at b=2, and quantifies the speedup the
paper's scheme buys as precision grows — the motivation for symmetric
operand widths.
"""

from __future__ import annotations

from repro.core import systolic as sa


def scaling_table(n: int = 1000) -> list[dict]:
    rows = []
    for b in range(1, 17):
        bismo = sa.bismo_dot_cycles(b, b, n)
        bitsmm = sa.bitsmm_dot_cycles(b, n)
        rows.append(dict(bits=b, n=n, bismo_cycles=bismo, bitsmm_cycles=bitsmm,
                         speedup=bismo / bitsmm))
    return rows


def asymmetric_table(n: int = 1000) -> list[dict]:
    """Where BISMO's asymmetric widths win: b_ml << b_mc (bitSMM must pad
    to b_max — the trade-off the paper concedes in §III-A)."""
    rows = []
    for b_mc, b_ml in ((16, 2), (16, 4), (8, 2), (8, 8), (4, 4)):
        bismo = sa.bismo_dot_cycles(b_mc, b_ml, n)
        bitsmm = sa.bitsmm_dot_cycles(max(b_mc, b_ml), n)
        rows.append(dict(b_mc=b_mc, b_ml=b_ml, bismo_cycles=bismo,
                         bitsmm_cycles=bitsmm, speedup=bismo / bitsmm))
    return rows


def run() -> list[tuple[str, float, str]]:
    out = []
    sym = scaling_table()
    assert all(r["speedup"] > 1 for r in sym if r["bits"] > 2)
    tie = [r for r in sym if r["bits"] == 2][0]
    assert abs(tie["speedup"] - 2 * 2 * 1000 / (1001 * 2)) < 1e-9
    for r in sym:
        if r["bits"] in (2, 4, 8, 16):
            out.append((f"cycles/symmetric_b{r['bits']}", r["bitsmm_cycles"],
                        f"bismo={r['bismo_cycles']};speedup={r['speedup']:.2f}x"))
    for r in asymmetric_table():
        out.append((f"cycles/asym_{r['b_mc']}x{r['b_ml']}", r["bitsmm_cycles"],
                    f"bismo={r['bismo_cycles']};speedup={r['speedup']:.2f}x"))
    return out


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
