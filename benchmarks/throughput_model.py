"""Paper Tables II / III / IV and Figure 6, from the analytical SA model.

The paper's throughput numbers are pure functions of (topology, bit-width,
frequency) via Eq. 10; power/area are measured constants from the paper.
This benchmark regenerates every table, asserts the GOPS columns match
the published values, and recomputes the derived GOPS/W / GOPS/mm2.
"""

from __future__ import annotations

from repro.core import systolic as sa

# (cols, rows) -> (LUTs, FFs, power_W, paper_GOPS, paper_GOPS_W)  @300 MHz
TABLE_II = {
    (16, 4): (5630, 8762, 1.13, 1.2, 1.062),
    (32, 8): (29355, 35490, 2.125, 4.8, 2.259),
    (64, 16): (117836, 155586, 6.459, 19.2, 2.973),
}
TABLE_II_SBMWC = {(16, 4): (11418, 10807, 1.657, 1.2, 0.724)}

# asap7: (max_MHz, area_mm2, power_W, paper_peak_GOPS, target_MHz,
#         paper_target_GOPS, paper_GOPS_mm2, paper_GOPS_W)
TABLE_III_ASAP7 = {
    (16, 4): (1183, 0.008, 0.102, 4.73, 1000, 4, 500, 39.2),
    (32, 8): (1124, 0.029, 0.403, 17.98, 1000, 16, 552, 39.7),
    (64, 16): (1144, 0.118, 1.57, 73.22, 1000, 64, 542, 40.8),
}
TABLE_III_NANGATE45 = {
    (16, 4): (748, 0.094, 0.214, 2.99, 500, 2, 21.28, 9.35),
    (32, 8): (685, 0.378, 0.809, 10.96, 500, 8, 21.16, 9.89),
    (64, 16): (643, 1.484, 3.28, 41.15, 500, 32, 21.56, 9.76),
}

BITS = 16  # all paper tables are 16-bit


def table2() -> list[dict]:
    rows = []
    for (w, h), (luts, ffs, pw, gops_paper, gopsw_paper) in TABLE_II.items():
        cfg = sa.SAConfig(w, h)
        gops = sa.gops(cfg, BITS, 300e6)
        assert abs(gops - gops_paper) < 1e-9, (w, h, gops, gops_paper)
        gopsw = gops / pw
        assert abs(gopsw - gopsw_paper) < 0.01
        rows.append(dict(topology=f"{w}x{h}", luts=luts, ffs=ffs, power_w=pw,
                         gops=gops, gops_per_w=round(gopsw, 3)))
    (w, h), (luts, ffs, pw, gops_paper, gopsw_paper) = next(iter(TABLE_II_SBMWC.items()))
    gops = sa.gops(sa.SAConfig(w, h), BITS, 300e6)
    assert abs(gops - gops_paper) < 1e-9
    rows.append(dict(topology=f"{w}x{h} SBMwC", luts=luts, ffs=ffs, power_w=pw,
                     gops=gops, gops_per_w=round(gops / pw, 3)))
    return rows


def table3() -> list[dict]:
    rows = []
    for lib, table in (("asap7", TABLE_III_ASAP7), ("nangate45", TABLE_III_NANGATE45)):
        for (w, h), (fmax, area, pw, peak_paper, ftgt, tgt_paper, gmm2_paper, gw_paper) in table.items():
            cfg = sa.SAConfig(w, h)
            peak = sa.gops(cfg, BITS, fmax * 1e6)
            tgt = sa.gops(cfg, BITS, ftgt * 1e6)
            assert abs(peak - peak_paper) < 0.01, (lib, w, h, peak, peak_paper)
            assert abs(tgt - tgt_paper) < 1e-9
            gmm2 = tgt / area
            gw = tgt / pw
            # paper rounds these columns; stay within 2.5%
            assert abs(gmm2 - gmm2_paper) / gmm2_paper < 0.025, (lib, w, h, gmm2)
            assert abs(gw - gw_paper) / gw_paper < 0.025
            rows.append(dict(lib=lib, topology=f"{w}x{h}", max_mhz=fmax,
                             area_mm2=area, power_w=pw, peak_gops=round(peak, 2),
                             target_gops=tgt, gops_mm2=round(gmm2, 1),
                             gops_w=round(gw, 1)))
    return rows


def table4() -> list[dict]:
    """SOTA comparison (paper Table IV): our rows derived, prior rows quoted."""
    ours_fpga = sa.gops(sa.SAConfig(64, 16), BITS, 300e6)
    ours_asap7 = sa.gops(sa.SAConfig(64, 16), BITS, 1144e6)
    return [
        dict(design="Opt. BISMO [34]", platform="ZU3EG", gops=60.0, gops_w=8.33),
        dict(design="bitSMM 64x16", platform="ZCU104", gops=round(ours_fpga, 2),
             gops_w=round(ours_fpga / 6.459, 2)),
        dict(design="FSSA [37]", platform="28nm", gops=25.75, gops_w=258.0),
        dict(design="bitSMM 64x16", platform="asap7", gops=round(ours_asap7, 2),
             gops_w=round(ours_asap7 / 1.57, 1)),
    ]


def figure6() -> list[dict]:
    """Peak OP/cycle vs operand width for the three topologies."""
    rows = []
    for w, h in ((16, 4), (32, 8), (64, 16)):
        cfg = sa.SAConfig(w, h)
        for bits in range(1, 17):
            rows.append(dict(topology=f"{w}x{h}", bits=bits,
                             op_per_cycle=sa.peak_op_per_cycle(cfg, bits)))
    return rows


def run() -> list[tuple[str, float, str]]:
    out = []
    for row in table2():
        out.append((f"table2/{row['topology'].replace(' ', '_')}", row["gops"],
                    f"gops_w={row['gops_per_w']}"))
    for row in table3():
        out.append((f"table3/{row['lib']}/{row['topology']}", row["peak_gops"],
                    f"target_gops={row['target_gops']};gops_mm2={row['gops_mm2']}"))
    for row in table4():
        out.append((f"table4/{row['design'].replace(' ', '_')}", row["gops"],
                    f"gops_w={row['gops_w']}"))
    f6 = figure6()
    for bits in (1, 8, 16):
        pts = {r["topology"]: r["op_per_cycle"] for r in f6 if r["bits"] == bits}
        out.append((f"figure6/bits={bits}", pts["64x16"],
                    ";".join(f"{k}={v:.1f}" for k, v in pts.items())))
    return out


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
