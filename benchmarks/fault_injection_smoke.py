"""CI fault-injection smoke gate: seeded SEUs at every fault site.

Serves a fixed greedy workload twice on a ``scrub``-mode continuous
batching engine — once clean (the reference tokens), once with a
seed-fixed :class:`~repro.runtime.faults.FaultInjector` flipping one bit
at *each* of the seven fault sites (packed plane words, sign words,
occupancy bitmaps, ABFT column checksums, epilogue scales, KV pages, KV
scales) on consecutive engine iterations. The gate hard-fails (exit 1)
unless

* every injected flip is detected (ABFT at the consuming matmul, the
  params fingerprint audit, or the per-slot KV checksum audit — any
  layer counts, silence does not);
* at least one scrub ran (detection without repair is not recovery);
* the faulted run's tokens are bit-identical to the clean run for every
  request (scrub-and-retry for weight-state faults, requeue-and-
  regenerate for KV faults — greedy decoding makes both exact).

Everything is seeded (weights, prompts, flip sites), so a failure
reproduces locally with ``PYTHONPATH=src python
benchmarks/fault_injection_smoke.py``.
"""

from __future__ import annotations

import sys

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.precision import PrecisionPolicy
from repro.launch.serve import ContinuousBatchingEngine
from repro.models.transformer import init_params
from repro.runtime.faults import FaultInjector
from repro.runtime.scheduler import Request

ARCH = "granite-3-8b"
# one flip per site, consecutive iterations, fixed RNG seed
SPEC = "planes@2,sign@3,occupancy@4,checksum@5,scale@6,kv@7,kv_scale@8;seed=11"
LENS, GEN, N_SLOTS = [4, 8], 12, 2


def _requests(cfg):
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (s,)),
                max_new_tokens=GEN, arrival_step=0)
        for i, s in enumerate(LENS)
    ]


def main() -> int:
    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    policy = PrecisionPolicy.uniform(
        8, 8, variant="booth", level="bitplane", integrity="scrub"
    )
    engine = ContinuousBatchingEngine(
        cfg, params, policy, n_slots=N_SLOTS, max_len=max(LENS) + GEN
    )
    res_ref, _ = engine.run(_requests(cfg))  # warm jits + reference tokens

    injector = FaultInjector(SPEC)
    res_f, stats = engine.run(_requests(cfg), injector=injector)
    integ = stats.get("integrity", {})
    detected = len(injector.events) - len(injector.undetected)

    print(f"[fault-smoke] spec: {SPEC}")
    print(
        f"[fault-smoke] injected {len(injector.events)}, detected {detected}, "
        f"scrubs {integ.get('scrubs', 0)}, step_retries "
        f"{integ.get('step_retries', 0)}, kv_alarms {integ.get('kv_alarms', 0)}, "
        f"requeued {integ.get('requeued', 0)}"
    )
    for e in injector.events:
        mark = "detected" if e.detected else "UNDETECTED"
        print(
            f"[fault-smoke]   {e.site}@{e.step} {e.leaf} "
            f"byte {e.byte} bit {e.bit}: {mark}"
        )

    fails: list[str] = []
    if not injector.events:
        fails.append("injector ran but recorded no FaultEvents")
    for e in injector.undetected:
        fails.append(
            f"undetected fault: {e.site}@{e.step} {e.leaf} byte {e.byte} "
            f"bit {e.bit} — a protection layer went silent"
        )
    if integ.get("scrubs", 0) < 1:
        fails.append("no scrub ran despite injected weight-state faults")
    for rid, want in res_ref.items():
        got = res_f.get(rid)
        if got is None:
            fails.append(f"request {rid} produced no tokens in the faulted run")
        elif not np.array_equal(got, want):
            fails.append(
                f"request {rid}: tokens diverged after injected faults "
                "(recovery is supposed to be bit-identical under greedy)"
            )

    if fails:
        print(f"[fault-smoke] FAILED ({len(fails)} problem(s)):")
        for f_ in fails:
            print(f"[fault-smoke]   - {f_}")
        return 1
    print(
        f"[fault-smoke] PASSED: {len(injector.events)}/{len(injector.events)} "
        "faults detected, tokens bit-identical after recovery"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
