"""Serving bench: continuous batching + int8 KV vs the lockstep baseline.

Drives the mixed-length / staggered-arrival scenario the lockstep engine
cannot express natively: prompts of several lengths arrive a few decode
steps apart, the continuous-batching engine admits them into free slots
mid-flight, and the lockstep baseline serves the same requests as
per-request batch-1 runs (its only exact option for mixed lengths).

Reports, into the ``serving`` section of BENCH_kernel.json:

* decode throughput (tok/s) for continuous batching (int8 and bf16 KV)
  vs the lockstep baseline on this host;
* measured KV-cache bytes at bf16 vs int8 (+ the full-config per-token
  accounting — the TPU HBM-traffic win, 1.94x at head_dim 128);
* a ``parity`` verdict: continuous batching with ``--no-kv-quant``
  semantics must reproduce every lockstep request bit for bit — the
  invariant the CI regression gate fails the build on;
* a ``precision_sweep`` column: decode tok/s at 8/6/4-bit from ONE 8-bit
  weight decomposition (``set_precision`` plane-prefix truncation — the
  paper's runtime reconfiguration as a serving feature), with a gated
  verdict that zero weight re-quantization/decomposition ran during the
  sweep and every dialed plan resolved to a cache-consuming route;
* a ``sparsity_sweep`` section (ISSUE 5): decode tok/s with occupancy
  sparsity off / gate / compact, Booth bitplane, at full-width (8-bit)
  and narrow-checkpoint (4-bit values in the 8-bit cache) weights —
  compaction drops the identically-zero high Booth planes the narrow
  values sign-extend into, shrinking the plane-pair grid on every
  backend; gating needs the Pallas kernels' predicated MXU passes, so on
  this jnp host it is a parity column, not a wall-clock one. Tokens must
  match dense bit for bit (hard CI gate) and compact-vs-dense at the
  narrow width is floor-checked (``check_bench_regression
  --sparsity-floor``).

* an ``integrity`` section (ISSUE 6): decode tok/s with ABFT + audits on
  (``detect``) vs off, token parity between the two, and a seeded
  fault-injection run against a ``scrub`` engine that must detect every
  flipped bit and recover bit-identical tokens. Overhead is gated by
  ``check_bench_regression --integrity-ceiling``; the verdicts ride the
  hard parity gate.

* an ``autopilot`` section (ISSUE 7): a scripted overload ramp served by
  a static 8-bit engine vs the SLA-autopilot engine. The autopilot must
  hold the configured p99 queue-step SLA that the static baseline
  demonstrably exceeds, by descending precision tiers and shedding only
  past the lowest tier; every finished request must match a single-tier
  run of its admission tier bit for bit (never-degraded traffic ==
  static 8-bit run exactly). ``check_bench_regression`` hard-fails on
  the SLA and parity verdicts.

* a ``tp_serving`` section (PR 8): continuous-batching decode through the
  tensor-parallel packed-plane path at model_parallel = 1/2/4 on virtual
  CPU devices — decode tok/s (smoke signal only on one physical CPU),
  per-device plane-cache bytes (must shrink ~1/model_parallel, gated by
  ``check_bench_regression --tp-shrink-slack``), and token parity
  against the single-device oracle (hard CI gate).

* a ``paged_serving`` section (PR 9): the paged-KV engine — block-table
  indirection, chunked prefill, copy-on-write shared-prefix reuse — on a
  slot-churn ramp where 80% of the prompts open on one shared prefix.
  Token parity vs the dense engine (chunked AND monolithic prefill) is a
  hard CI gate; peak resident KV bytes must sit below the dense
  residency by ``check_bench_regression --kv-shrink-floor``; decode
  inter-token p99 (per-iteration wall incl. prefill work) contrasts
  chunked against monolithic prefill stalls.

CLI: ``python benchmarks/serving_bench.py [--smoke] [--json PATH]
[--precision-sweep] [--sparsity-sweep] [--integrity-sweep]
[--autopilot-sweep] [--tp-sweep] [--paged-sweep]`` (each sweep alone).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

# the tp_serving sweep needs 8 virtual CPU devices; no-op when driven
# from kernel_bench.py (which sets this before jax initializes)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import bitplanes as bp
from repro.core import plan as plan_mod
from repro.core.precision import PrecisionPolicy
from repro.launch.serve import ContinuousBatchingEngine, Engine
from repro.models import quant
from repro.models.transformer import init_params
from repro.runtime.faults import FaultInjector
from repro.runtime.scheduler import Request

ARCH = "granite-3-8b"


def _lockstep_baseline(cfg, params, policy, requests, gen):
    """Serve the mixed-length workload the only way the lockstep engine
    can do it exactly: one batch-1 run per request, back to back. Engines
    are built and warmed outside the timed region (a new Engine closure
    re-jits; the CB side is likewise measured warm)."""
    engines = {
        req.rid: Engine(cfg, params, policy, max_len=req.tokens.size + gen)
        for req in requests
    }
    for req in requests:  # warm: compile prefill + decode per length
        engines[req.rid].generate(jnp.asarray(req.tokens)[None, :], gen)
    outputs = {}
    t0 = time.time()
    for req in requests:
        toks, _ = engines[req.rid].generate(jnp.asarray(req.tokens)[None, :], gen)
        outputs[req.rid] = np.asarray(toks[0])
    wall = max(time.time() - t0, 1e-9)
    total = gen * len(requests)
    return outputs, total / wall


def precision_sweep(cfg, params, smoke: bool = False) -> dict:
    """Decode tok/s at 8/6/4 bits from one 8-bit bitplane decomposition.

    The engine is built (weights quantized + decomposed) once; each tier
    is just ``set_precision`` — a plan swap. A wrapped
    ``decompose_linear_weight`` proves no weight re-decomposition runs
    during the sweep, and the plan registry is audited to show every
    dialed matmul resolved to a truncated-cache route (the "no
    re-quantization step in the trace" acceptance criterion).
    """
    policy = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane")
    if smoke:
        lens, gen, n_slots = [4, 8], 6, 2
    else:
        lens, gen, n_slots = [8, 8, 16, 16], 16, 4
    engine = ContinuousBatchingEngine(
        cfg, params, policy, n_slots=n_slots, max_len=max(lens) + gen
    )

    def requests():
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (s,)),
                    max_new_tokens=gen, arrival_step=0)
            for i, s in enumerate(lens)
        ]

    decompose_calls = {"n": 0}
    real_decompose = quant.decompose_linear_weight

    def counting(*a, **kw):
        decompose_calls["n"] += 1
        return real_decompose(*a, **kw)

    tok_per_s = {}
    quant.decompose_linear_weight = counting
    try:
        for bits in (8, 6, 4):
            engine.set_precision(None if bits == 8 else bits)
            engine.run(requests())  # warm: compile this tier's steps
            _, stats = engine.run(requests())
            tok_per_s[f"w{bits}a{bits}"] = round(stats["tok_per_s"], 2)
    finally:
        quant.decompose_linear_weight = real_decompose

    # Registry audit: every plan resolved at a dialed width must consume
    # the stored decomposition (truncation), never requantize the weight.
    audit = plan_mod.truncation_audit()
    truncated_ok = decompose_calls["n"] == 0 and audit["truncated_ok"]
    return {
        "workload": {"prompt_lens": lens, "gen": gen, "n_slots": n_slots},
        "stored_bits": 8,
        "tok_per_s": tok_per_s,
        "speedup_4_vs_8": round(tok_per_s["w4a4"] / tok_per_s["w8a8"], 2),
        "speedup_6_vs_8": round(tok_per_s["w6a6"] / tok_per_s["w8a8"], 2),
        "requantize_calls_during_sweep": decompose_calls["n"],
        "truncated_plan_routes": audit["routes"],
        "verdict": "ok" if truncated_ok else "requantized",
    }


def sparsity_sweep(cfg, params, smoke: bool = False) -> dict:
    """Decode tok/s with sparsity off/gate/compact at two effective widths.

    ``w8``: weights quantized at the full 8-bit storage width — every
    plane is occupied somewhere, compaction finds nothing to drop, and the
    tier doubles as a no-regression check. ``w4eff``: the narrow-checkpoint
    deployment (``value_bits=4`` — 4-bit values served from the uniform
    8-bit plane cache): Booth digits of sign-extended narrow integers are
    identically zero above bit 4, so compaction halves the weight-plane
    set and the plane-pair grid with it. Tokens must be bit-identical to
    dense in every cell (the ``parity`` dict CI hard-fails on); the
    compact-vs-dense ratio at w4eff is the ``--sparsity-floor`` gate.
    """
    if smoke:
        lens, gen, n_slots = [4, 8], 6, 2
    else:
        lens, gen, n_slots = [8, 8, 16, 16], 16, 4

    def requests():
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (s,)),
                    max_new_tokens=gen, arrival_step=0)
            for i, s in enumerate(lens)
        ]

    tok_per_s, planes_kept, tokens = {}, {}, {}
    for tier, value_bits in (("w8", None), ("w4eff", 4)):
        for sparsity in ("off", "gate", "compact"):
            policy = PrecisionPolicy.uniform(
                8, 8, variant="booth", level="bitplane", sparsity=sparsity
            )
            engine = ContinuousBatchingEngine(
                cfg, params, policy, n_slots=n_slots, max_len=max(lens) + gen,
                value_bits=value_bits,
            )
            engine.run(requests())  # warm: compile this tier's steps
            # best-of-2: identical warm runs swing ~1.5x on shared hosts;
            # the max is the least-interfered sample of the same work
            best = 0.0
            for _ in range(2):
                res, stats = engine.run(requests())
                best = max(best, stats["tok_per_s"])
            tok_per_s[f"{tier}_{sparsity}"] = round(best, 2)
            tokens[(tier, sparsity)] = res
            counts = {
                len(leaf.weights)
                for leaf in jax.tree_util.tree_leaves(
                    engine.q_params,
                    is_leaf=lambda x: isinstance(x, bp.WeightPlanes),
                )
                if isinstance(leaf, bp.WeightPlanes)
            }
            planes_kept[f"{tier}_{sparsity}"] = sorted(counts)

    parity = {}
    for tier in ("w8", "w4eff"):
        ok = "ok"
        for sparsity in ("gate", "compact"):
            for rid, want in tokens[(tier, "off")].items():
                if not np.array_equal(tokens[(tier, sparsity)][rid], want):
                    ok = "mismatch"
        parity[f"sparsity_tokens_{tier}"] = ok

    return {
        "workload": {"prompt_lens": lens, "gen": gen, "n_slots": n_slots},
        "variant": "booth",
        "stored_bits": 8,
        "tok_per_s": tok_per_s,
        "planes_kept": planes_kept,
        "speedup_compact_vs_dense_4bit": round(
            tok_per_s["w4eff_compact"] / tok_per_s["w4eff_off"], 2
        ),
        "speedup_compact_vs_dense_8bit": round(
            tok_per_s["w8_compact"] / tok_per_s["w8_off"], 2
        ),
        "parity": parity,
        "note": (
            "w4eff = 4-bit weight values served from the 8-bit plane cache "
            "(narrow checkpoint); compact drops the identically-zero high "
            "Booth planes. gate only skips MXU passes inside the Pallas "
            "kernels, so on a jnp host its wall-clock matches 'off' and "
            "only the parity column is meaningful"
        ),
    }


def integrity_sweep(cfg, params, smoke: bool = False) -> dict:
    """ABFT/checksum serving cost + injected-SEU detection and recovery.

    Three verdicts, all hard-gated in CI (``parity`` dict +
    ``check_bench_regression --integrity-ceiling``):

    * ``detect`` overhead: decode tok/s with per-matmul ABFT row-sum
      checks, per-iteration params audits and KV slot checksums, vs the
      same engine with integrity off. Acceptance: within the CI ceiling
      (default 1.15x).
    * token parity: the detect engine must emit bit-identical tokens to
      the unchecked engine (checks are read-only).
    * fault run: a seeded :class:`FaultInjector` flips one weight-plane
      bit and one KV bit mid-serving against a ``scrub`` engine; every
      flip must be detected AND the output tokens must still match the
      fault-free run bit for bit (scrub-and-retry recovery).
    """
    if smoke:
        lens, gen, n_slots = [4, 8], 6, 2
    else:
        lens, gen, n_slots = [8, 8, 16, 16], 16, 4

    def requests():
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (s,)),
                    max_new_tokens=gen, arrival_step=0)
            for i, s in enumerate(lens)
        ]

    # Audits (full-params fingerprint + KV slot checksums) amortize over
    # iterations in production; ABFT stays per-matmul. The fault-run
    # engine below keeps audit_interval=1 for tightest detection latency.
    audit_interval = 4
    tok_per_s, tokens = {}, {}
    detect_stats: dict = {}
    for mode in ("off", "detect"):
        policy = PrecisionPolicy.uniform(
            8, 8, variant="booth", level="bitplane", integrity=mode
        )
        engine = ContinuousBatchingEngine(
            cfg, params, policy, n_slots=n_slots, max_len=max(lens) + gen,
            audit_interval=audit_interval,
        )
        engine.run(requests())  # warm: compile this mode's steps
        best, res = 0.0, {}
        for _ in range(2):
            res, stats = engine.run(requests())
            best = max(best, stats["tok_per_s"])
            if mode == "detect":
                detect_stats = stats.get("integrity", {})
        tok_per_s[mode] = round(best, 2)
        tokens[mode] = res

    # Injected-fault run: scrub engine, one plane flip + one KV flip at
    # seed-fixed iterations. Same greedy workload, so recovery == the
    # fault-free tokens, bit for bit.
    spec = "planes@2,kv@3;seed=7"
    policy = PrecisionPolicy.uniform(
        8, 8, variant="booth", level="bitplane", integrity="scrub"
    )
    engine = ContinuousBatchingEngine(
        cfg, params, policy, n_slots=n_slots, max_len=max(lens) + gen
    )
    engine.run(requests())  # warm
    injector = FaultInjector(spec)
    res_f, stats_f = engine.run(requests(), injector=injector)
    recovered = all(
        np.array_equal(res_f.get(rid), want) for rid, want in tokens["off"].items()
    )
    detect_parity = all(
        np.array_equal(tokens["detect"].get(rid), want)
        for rid, want in tokens["off"].items()
    )

    parity = {
        "integrity_tokens_detect_vs_off": "ok" if detect_parity else "mismatch",
        "fault_detection": (
            "ok" if injector.events and not injector.undetected else "missed"
        ),
        "fault_recovery_tokens": "ok" if recovered else "mismatch",
    }
    return {
        "workload": {"prompt_lens": lens, "gen": gen, "n_slots": n_slots},
        "variant": "booth",
        "audit_interval": audit_interval,
        "tok_per_s": tok_per_s,
        "overhead_detect_vs_off_x": round(
            tok_per_s["off"] / max(tok_per_s["detect"], 1e-9), 3
        ),
        "detect_stats": {
            k: detect_stats[k]
            for k in ("abft_checks", "abft_alarms", "audits", "audit_alarms",
                      "kv_checks", "kv_alarms")
            if k in detect_stats
        },
        "fault_run": {
            "spec": spec,
            "injected": len(injector.events),
            "detected": len(injector.events) - len(injector.undetected),
            "scrubs": stats_f.get("integrity", {}).get("scrubs", 0),
            "step_retries": stats_f.get("integrity", {}).get("step_retries", 0),
        },
        "parity": parity,
        "note": (
            "detect = per-matmul ABFT row-sum checks + per-iteration params "
            "fingerprint audit + per-slot KV checksums, all inside the "
            "serving loop; fault run injects one weight-plane bit flip and "
            "one KV bit flip (seeded) against a scrub engine and requires "
            "100% detection plus bit-identical recovered tokens"
        ),
    }


def autopilot_sweep(cfg, params, smoke: bool = False) -> dict:
    """Scripted overload ramp: static 8-bit vs the SLA autopilot engine.

    The workload oversubscribes the slot array (``n_req >> n_slots``
    arriving within a few steps), so a static 8-bit engine queues the
    tail far past the SLA. The autopilot engine under the same ramp must
    hold p99 queue-wait within ``sla_queue_steps`` by descending
    precision tiers and, only past the lowest tier, shedding the queue
    tail (DESIGN.md §10). Three hard verdicts ride the CI parity gate:

    * ``autopilot_sla`` / ``static_overload``: the autopilot holds the
      SLA the static baseline demonstrably exceeds (if the ramp stops
      overloading the static engine the check is vacuous — that fails
      too);
    * ``undegraded_tokens_vs_static``: requests admitted at the widest
      tier must emit tokens bit-identical to the static 8-bit run —
      mixed-tier decode is invisible to never-degraded traffic;
    * ``degraded_tokens_vs_single_tier``: requests admitted at a lower
      tier must match a single-tier run of that tier bit for bit — the
      per-slot tier contract, not an approximation;
    * ``shed_only_at_lowest``: every shed reason names the lowest tier
      (the ladder is exhausted before any request is dropped).
    """
    from repro.runtime.autopilot import AutopilotPolicy

    policy = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane")
    if smoke:
        plen, gen, n_slots, n_req, sla = 4, 5, 2, 8, 6
    else:
        plen, gen, n_slots, n_req, sla = 8, 8, 2, 12, 8
    ap_policy = AutopilotPolicy(
        sla_queue_steps=sla,
        degrade_patience=2,
        upgrade_patience=4,
        cooldown_steps=2,
        shadow_frac=0.5,
    )

    def requests():
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (plen,)),
                    max_new_tokens=gen, arrival_step=i // n_slots)
            for i in range(n_req)
        ]

    kw = dict(n_slots=n_slots, max_len=plen + gen)
    ap_engine = ContinuousBatchingEngine(
        cfg, params, policy, autopilot=ap_policy, **kw
    )
    ap_engine.run(requests())  # warm: compiles every tier it descends through
    ap_res, ap_stats = ap_engine.run(requests())
    apst = ap_stats["autopilot"]

    static = ContinuousBatchingEngine(cfg, params, policy, **kw)
    static.run(requests())  # warm
    st_res, st_stats = static.run(requests())

    # Per-tier contract parity: each finished request must match a
    # single-tier run of its admission tier, bit for bit. Tier w8a8
    # reuses the measured static run (same engine, same compiled steps).
    tier_runs = {"w8a8": st_res}
    lowest_w = min(w for _, w in ap_engine._tiers)
    parity = {"undegraded_tokens_vs_static": "ok",
              "degraded_tokens_vs_single_tier": "ok"}
    for rid_s, tier_name in sorted(apst["request_tiers"].items()):
        rid = int(rid_s)
        if tier_name not in tier_runs:
            w = int(tier_name.split("a")[0][1:])
            static.set_precision(None if w == 8 else w)
            tier_runs[tier_name], _ = static.run(requests())
        want = tier_runs[tier_name].get(rid)
        got = ap_res.get(rid)
        if got is None or want is None or not np.array_equal(got, want):
            key = ("undegraded_tokens_vs_static" if tier_name == "w8a8"
                   else "degraded_tokens_vs_single_tier")
            parity[key] = "mismatch"

    shed_reasons = [
        r for r in ap_stats["failed"].values() if r.startswith("overload:")
    ]
    parity["shed_only_at_lowest"] = (
        "ok" if all(f"tier w{lowest_w}" in r for r in shed_reasons)
        else "mismatch"
    )
    ap_p99 = apst["p99_queue_steps"]
    st_p99 = st_stats["p99_queue_steps"]
    parity["autopilot_sla"] = "ok" if ap_p99 <= sla else "violated"
    parity["static_overload"] = "ok" if st_p99 > sla else "vacuous"

    total_toks = max(sum(apst["tier_tokens"].values()), 1)
    return {
        "workload": {
            "prompt_len": plen, "gen": gen, "n_slots": n_slots,
            "n_requests": n_req, "arrival": "i // n_slots",
        },
        "sla_queue_steps": sla,
        "tok_per_s": {
            "static_w8": round(st_stats["tok_per_s"], 2),
            "autopilot": round(ap_stats["tok_per_s"], 2),
        },
        "p99_queue_steps": {
            "static_w8": round(st_p99, 2),
            "autopilot": round(ap_p99, 2),
        },
        "shed": apst["shed"],
        "switches": [[s, list(t), r] for s, t, r in apst["switches"]],
        "tier_token_frac": {
            name: round(n / total_toks, 3)
            for name, n in sorted(apst["tier_tokens"].items())
        },
        "shadow": {
            "probes": apst["shadow_probes"],
            "kl_ewma": (
                None if apst["shadow_kl_ewma"] is None
                else round(apst["shadow_kl_ewma"], 5)
            ),
        },
        "parity": parity,
        "note": (
            "same burst workload through a static 8-bit engine and the "
            "autopilot engine; the autopilot descends the tier ladder "
            "under queue pressure and sheds the deadline-hopeless tail "
            "only past the lowest tier. Parity compares each finished "
            "request against a single-tier run of its admission tier "
            "(the per-request tier contract)"
        ),
    }


def tp_serving_sweep(cfg, params, smoke: bool = False) -> dict:
    """Tensor-parallel packed-plane serving (DESIGN.md §11): decode tok/s
    and per-device plane-cache bytes at model_parallel = 1/2/4, with the
    model=1 run as the token-parity oracle.

    Runs on virtual CPU devices in CI (``XLA_FLAGS=--xla_force_host_
    platform_device_count=8``), so the wall-clock columns are smoke
    signals only — sharding one physical CPU across 8 virtual devices
    speeds nothing up. The content the gate consumes is (a) the parity
    dict (sharded tokens must equal the single-device oracle bit for
    bit, hard CI fail) and (b) the per-device plane-cache footprint,
    which must shrink ~1/model_parallel (``check_bench_regression
    --tp-shrink-slack``).

    The sweep builds its own config/params: the stock reduced config has
    2 KV heads (indivisible at model=4), so n_kv_heads is bumped to 4 and
    the SAME modified config serves every model_parallel *including* the
    oracle — apples to apples.
    """
    n_dev = len(jax.devices())
    meshes = [p for p in (1, 2, 4) if p <= n_dev]
    if len(meshes) < 3:
        return {
            "skipped": (
                f"needs 4 devices for model_parallel=4, found {n_dev} — "
                "set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "(kernel_bench.py sets it by default when unset)"
            ),
        }
    tcfg = dataclasses.replace(cfg, n_kv_heads=4)
    tparams = init_params(tcfg, jax.random.PRNGKey(0))
    policy = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane")
    if smoke:
        lens, gen, n_slots, stagger = [4, 8, 6], 4, 2, 1
    else:
        lens, gen, n_slots, stagger = [8, 32, 16, 64], 12, 2, 2

    def requests():
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, tcfg.vocab_size, (s,)),
                    max_new_tokens=gen, arrival_step=i * stagger)
            for i, s in enumerate(lens)
        ]

    tok_per_s, plane_bytes, results = {}, {}, {}
    for mp in meshes:
        engine = ContinuousBatchingEngine(
            tcfg, tparams, policy, n_slots=n_slots, max_len=max(lens) + gen,
            model_parallel=mp,
        )
        engine.run(requests())  # warm: compile the sharded prefill + decode
        res, stats = engine.run(requests())
        tok_per_s[f"model{mp}"] = round(stats["tok_per_s"], 2)
        plane_bytes[f"model{mp}"] = engine.plane_cache_bytes_per_device()
        results[mp] = {rid: np.asarray(t) for rid, t in res.items()}

    parity = {}
    for mp in meshes[1:]:
        ok = sorted(results[mp]) == sorted(results[1]) and all(
            np.array_equal(results[mp][rid], results[1][rid])
            for rid in results[1]
        )
        parity[f"tp{mp}_tokens_vs_single_device"] = "ok" if ok else "mismatch"

    base_bytes = plane_bytes["model1"]
    return {
        "workload": {
            "prompt_lens": lens,
            "gen": gen,
            "n_slots": n_slots,
            "arrival_stagger_steps": stagger,
            "n_kv_heads": tcfg.n_kv_heads,
        },
        "model_parallel": meshes,
        "tok_per_s": tok_per_s,
        "plane_cache_bytes_per_device": plane_bytes,
        "shrink_x": {
            f"model{mp}": round(base_bytes / plane_bytes[f"model{mp}"], 3)
            for mp in meshes[1:]
        },
        "parity": parity,
        "note": (
            "virtual CPU devices: tok/s columns are smoke signals, not "
            "speedups; the gated content is token parity vs the model=1 "
            "oracle and the ~1/P per-device plane-cache footprint "
            "(col-parallel q/k/v/gate/up, row-parallel o/down, "
            "vocab-parallel lm_head)"
        ),
    }


def paged_serving_sweep(cfg, params, smoke: bool = False) -> dict:
    """Paged KV serving (DESIGN.md §12): residency, decode p99, parity.

    High-slot-churn workload where 80% of the prompts open on one shared
    system prefix, served three ways from the same request stream:

    * the **dense** engine — the token-parity oracle, whose cache
      residency is ``n_slots * max_len`` positions no matter what the
      prompts look like;
    * the **paged** engine with chunked prefill + CoW prefix sharing —
      the shipping configuration. Its ``kv_bytes_resident_peak`` (pages
      ever live at once x per-page bytes) must sit below the dense
      residency by ``check_bench_regression --kv-shrink-floor``;
    * the paged engine with **monolithic** prefill — the decode-p99
      contrast: a full prefill stalls the whole engine iteration, while
      chunked prefill bounds the stall to one chunk, so the chunked
      engine's inter-token p99 under the prefill-heavy ramp stays below
      the monolithic engine's (reported as ``decode_iter_p99_ms``; the
      wall-clock ratio is host-noisy, so the hard CI gates are the two
      token-parity verdicts and the residency floor).
    """
    policy = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane")
    ps = 8
    if smoke:
        n_slots, gen, max_len, n_req = 3, 4, 48, 10
        prefix_len, body_max = 16, 12
    else:
        n_slots, gen, max_len, n_req = 4, 8, 96, 20
        prefix_len, body_max = 32, 24
    shared_n = int(n_req * 0.8)

    def requests():
        rng = np.random.default_rng(0)
        prefix = rng.integers(0, cfg.vocab_size, (prefix_len,))
        body = np.random.default_rng(1)
        reqs = []
        for i in range(n_req):
            shared = i % n_req < shared_n  # first 80% share the prefix
            blen = int(body.integers(4, body_max))
            toks = (
                np.concatenate([prefix, body.integers(0, cfg.vocab_size, (blen,))])
                if shared
                else body.integers(0, cfg.vocab_size, (prefix_len + blen,))
            )
            reqs.append(Request(
                rid=i, tokens=toks, max_new_tokens=gen,
                arrival_step=i,  # tight ramp: prefills land mid-decode
                shared_prefix_len=prefix_len if shared else 0,
            ))
        return reqs

    kw = dict(n_slots=n_slots, max_len=max_len)
    dense = ContinuousBatchingEngine(cfg, params, policy, **kw)
    dense.run(requests())  # warm: compile per-length prefills + decode
    res_dense, st_dense = dense.run(requests())

    chunked = ContinuousBatchingEngine(
        cfg, params, policy, page_size=ps, prefill_chunk=ps,
        share_prefixes=True, **kw,
    )
    chunked.run(requests())  # warm
    res_ch, st_ch = chunked.run(requests())

    mono = ContinuousBatchingEngine(
        cfg, params, policy, page_size=ps, share_prefixes=True, **kw,
    )
    mono.run(requests())  # warm
    res_mono, st_mono = mono.run(requests())

    def same(res):
        return sorted(res) == sorted(res_dense) and all(
            np.array_equal(res[rid], res_dense[rid]) for rid in res_dense
        )

    pg = st_ch["paging"]
    dense_bytes = st_dense["kv_cache_bytes"]
    resident = max(pg["kv_bytes_resident_peak"], 1)
    return {
        "workload": {
            "n_requests": n_req, "gen": gen, "n_slots": n_slots,
            "max_len": max_len, "prefix_len": prefix_len,
            "shared_frac": round(shared_n / n_req, 2),
            "arrival": "i (1-step ramp)",
        },
        "page_size": ps,
        "prefill_chunk": ps,
        "tok_per_s": {
            "dense": round(st_dense["tok_per_s"], 2),
            "paged_chunked": round(st_ch["tok_per_s"], 2),
            "paged_monolithic": round(st_mono["tok_per_s"], 2),
        },
        "decode_iter_p99_ms": {
            "dense_monolithic": round(st_dense["decode_iter_p99_ms"], 2),
            "paged_chunked": round(st_ch["decode_iter_p99_ms"], 2),
            "paged_monolithic": round(st_mono["decode_iter_p99_ms"], 2),
        },
        "kv_bytes": {
            "dense_resident": dense_bytes,
            "paged_resident_peak": pg["kv_bytes_resident_peak"],
            "page_nbytes": pg["page_nbytes"],
            "peak_used_pages": pg["peak_used_pages"],
        },
        "kv_shrink_x": round(dense_bytes / resident, 3),
        "sharing": {
            "shared_prefix_hits": pg["shared_prefix_hits"],
            "prefix_entries": pg["prefix_entries"],
            "prefix_evictions": pg["prefix_evictions"],
        },
        "prefill_chunks": st_ch["prefill_chunks"],
        "parity": {
            "paged_chunked_tokens_vs_dense": "ok" if same(res_ch) else "mismatch",
            "paged_monolithic_tokens_vs_dense": (
                "ok" if same(res_mono) else "mismatch"
            ),
        },
        "note": (
            "kv_shrink_x = dense cache residency / peak paged page bytes "
            "at 80% shared prefixes under slot churn — the "
            "--kv-shrink-floor gate; decode_iter_p99_ms is per-iteration "
            "wall incl. prefill work (inter-token latency), where chunked "
            "prefill bounds the stall a monolithic prefill imposes"
        ),
    }


def tuned_tiles_sweep(cfg, params, smoke: bool = False) -> dict:
    """Tuned-vs-heuristic decode/prefill throughput (ISSUE 10).

    Three phases over the same decode-heavy and prefill-heavy workloads:

    1. **heuristic** — plain engines, tiles from ``auto_tiles``;
    2. **tuned (cold)** — the plan registry is cleared and engines are
       built with ``autotune=True`` against a persistent plan store
       (``REPRO_PLAN_STORE`` or a temp dir), so every plan build consults
       the roofline-pruned tuner and persists its winner;
    3. **tuned (warm)** — the registry is cleared again and a *fresh*
       tuner (zero counters) is attached to the same store, simulating a
       second process start: every consulted plan must be a store hit
       with **zero** tuning runs (the ``warm_start_zero_tune`` parity
       verdict CI hard-fails on).

    Tokens must be bit-identical across all three phases — tiles change
    the MXU pass schedule, never the integer arithmetic — and the
    tuned-vs-heuristic throughput ratios are floor-gated by
    ``check_bench_regression --tuned-floor``. On this jnp host tiles are
    inert (XLA fuses the contraction), so the tuner collapses each plan's
    candidate space to the single heuristic survivor and the ratios
    measure store-plumbing overhead (~1.0x); on a Pallas backend the same
    sweep measures real tile wins.
    """
    import tempfile

    from repro.runtime.plan_store import PlanStore

    policy = PrecisionPolicy.uniform(8, 8, variant="booth", level="bitplane")
    if smoke:
        n_slots = 2
        workloads = {"decode": ([4, 8], 6), "prefill": ([24, 32], 2)}
    else:
        n_slots = 4
        workloads = {"decode": ([8, 8, 16, 16], 16), "prefill": ([64, 96, 128, 128], 4)}

    store_dir = os.environ.get("REPRO_PLAN_STORE") or tempfile.mkdtemp(
        prefix="plan_store_"
    )
    store_path = os.path.join(store_dir, "plan_store.json")

    def requests(lens, gen):
        rng = np.random.default_rng(0)
        return [
            Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, (s,)),
                    max_new_tokens=gen, arrival_step=0)
            for i, s in enumerate(lens)
        ]

    def run_phase(autotune: bool):
        """Build one engine per workload; returns (tokens, tok/s) maps."""
        tokens, tps = {}, {}
        for name, (lens, gen) in workloads.items():
            engine = ContinuousBatchingEngine(
                cfg, params, policy, n_slots=n_slots, max_len=max(lens) + gen,
                autotune=autotune,
                plan_store_path=store_path if autotune else None,
            )
            engine.run(requests(lens, gen))  # warm: compile
            # best-of-3: identical warm runs swing >1.5x on shared hosts
            # and the gated ratio here is expected ~1.0, not a real win
            best = 0.0
            for _ in range(3):
                res, stats = engine.run(requests(lens, gen))
                metric = (
                    stats["tok_per_s"]
                    if name == "decode"
                    else stats["prefill_tokens"] / max(stats["wall_s"], 1e-9)
                )
                best = max(best, metric)
            tokens[name], tps[name] = res, round(best, 2)
        return tokens, tps

    registry = plan_mod.DEFAULT_REGISTRY
    try:
        registry.attach_tuner(None)
        base_tokens, base_tps = run_phase(autotune=False)

        registry.clear()  # every plan must re-resolve through the tuner
        cold_tokens, cold_tps = run_phase(autotune=True)
        cold = dict(registry.store_stats())

        # Second-process simulation: fresh tuner (zero counters), warm store.
        registry.attach_tuner(None)
        registry.clear()
        warm_tokens, warm_tps = run_phase(autotune=True)
        warm = dict(registry.store_stats())
    finally:
        registry.attach_tuner(None)

    token_parity = "ok"
    for name in workloads:
        for phase_tokens in (cold_tokens, warm_tokens):
            for rid, toks in base_tokens[name].items():
                if not np.array_equal(phase_tokens[name][rid], toks):
                    token_parity = "mismatch"

    # Zero tuning runs at warm start, and the store served every lookup
    # the cold phase resolved (hit counter == consulted-plan count).
    consulted = cold["store_hits"] + cold["store_misses"]
    warm_ok = (
        warm["tunes"] == 0
        and warm["store_misses"] == 0
        and warm["store_hits"] == consulted
        and warm["store_hits"] > 0
    )
    tuned_tps = {k: max(cold_tps[k], warm_tps[k]) for k in workloads}
    return {
        "workload": {
            name: {"prompt_lens": lens, "gen": gen, "n_slots": n_slots}
            for name, (lens, gen) in workloads.items()
        },
        "store": {
            "path": store_path,
            "fingerprint": cold.get("fingerprint"),
            "entries": PlanStore(store_path).entries(),
        },
        "hardware": {
            "name": cold.get("hardware"),
            "source": cold.get("hardware_source"),
        },
        "tok_per_s": {
            name: {
                "heuristic": base_tps[name],
                "tuned_cold": cold_tps[name],
                "tuned_warm": warm_tps[name],
            }
            for name in workloads
        },
        "tuned_vs_heuristic": {
            name: round(tuned_tps[name] / max(base_tps[name], 1e-9), 3)
            for name in workloads
        },
        "plan_counters": {"cold": cold, "warm": warm},
        "parity": {
            "tuned_tokens_vs_heuristic": token_parity,
            "warm_start_zero_tune": (
                "ok"
                if warm_ok
                else f"hits_{warm['store_hits']}_misses_{warm['store_misses']}"
                f"_tunes_{warm['tunes']}_expected_hits_{consulted}"
            ),
        },
        "note": (
            "prefill tok/s = prefill_tokens/wall on the prefill-heavy "
            "workload; decode tok/s = engine tok_per_s. best-of-3 per "
            "phase. On the jnp backend tiles are inert, so the ratios "
            "gate store plumbing at ~1.0x; Pallas backends measure real "
            "tile wins here"
        ),
    }


def serving_bench(json_path: str | None = None, smoke: bool = False):
    """Returns report rows; writes the ``serving`` JSON section."""
    from kernel_bench import JSON_PATH, _write_bench_section

    path = json_path or JSON_PATH
    cfg = get_reduced(ARCH)
    policy = PrecisionPolicy.uniform(8, 8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if smoke:
        lens, gen, n_slots, stagger = [4, 8], 4, 2, 1
    else:
        lens, gen, n_slots, stagger = [8, 32, 128], 16, 2, 2
    max_len = max(lens) + gen
    rng = np.random.default_rng(0)
    requests = [
        Request(
            rid=i,
            tokens=rng.integers(0, cfg.vocab_size, (s,)),
            max_new_tokens=gen,
            arrival_step=i * stagger,
        )
        for i, s in enumerate(lens)
    ]

    kw = dict(n_slots=n_slots, max_len=max_len)
    cb_q = ContinuousBatchingEngine(cfg, params, policy, kv_quant=True, **kw)
    cb_x = ContinuousBatchingEngine(cfg, params, policy, kv_quant=False, **kw)
    # warm the jits (per-prompt-length prefill + the decode step), then measure
    res_q, stats_q = cb_q.run(requests)
    res_q, stats_q = cb_q.run(requests)
    res_x, stats_x = cb_x.run(requests)
    res_x, stats_x = cb_x.run(requests)
    base, base_tps = _lockstep_baseline(cfg, params, policy, requests, gen)

    parity = "ok"
    for req in requests:
        if not np.array_equal(res_x[req.rid], base[req.rid]):
            parity = "mismatch"
    first_tok_parity = "ok"
    for req in requests:
        if res_q[req.rid][0] != base[req.rid][0]:
            first_tok_parity = "mismatch"

    sweep = precision_sweep(cfg, params, smoke=smoke)
    sparsity = sparsity_sweep(cfg, params, smoke=smoke)
    integrity = integrity_sweep(cfg, params, smoke=smoke)
    autopilot = autopilot_sweep(cfg, params, smoke=smoke)
    tp_serving = tp_serving_sweep(cfg, params, smoke=smoke)
    paged = paged_serving_sweep(cfg, params, smoke=smoke)
    # last: it clears and re-resolves the process plan registry (tuner
    # attach/detach), which the other sweeps must not see mid-flight
    tuned = tuned_tiles_sweep(cfg, params, smoke=smoke)

    kv_reduction = stats_x["kv_cache_bytes"] / stats_q["kv_cache_bytes"]
    # full-config accounting: the reduced head_dim understates the win
    d, full_d = cfg.head_dim, 128
    analytic = {
        "bf16_bytes_per_pos_head": 2 * 2 * d,
        "int8_bytes_per_pos_head": 2 * (d + 4),
        "reduction_x": round(2 * d / (d + 4), 3),
        "reduction_x_at_head_dim_128": round(2 * full_d / (full_d + 4), 3),
    }

    payload = {
        "bench": "serving",
        "arch": cfg.name,
        "workload": {
            "prompt_lens": lens,
            "gen": gen,
            "n_slots": n_slots,
            "arrival_stagger_steps": stagger,
        },
        "smoke": smoke,
        "tok_per_s": {
            "cb_int8_kv": round(stats_q["tok_per_s"], 2),
            "cb_bf16_kv": round(stats_x["tok_per_s"], 2),
            "lockstep_per_request": round(base_tps, 2),
            "cb_vs_lockstep_x": round(stats_q["tok_per_s"] / base_tps, 2),
        },
        "slot_utilization": round(stats_q["slot_utilization"], 3),
        "kv_bytes": {
            "bf16": stats_x["kv_cache_bytes"],
            "int8": stats_q["kv_cache_bytes"],
            "reduction_x": round(kv_reduction, 3),
            "analytic": analytic,
        },
        "precision_sweep": sweep,
        "parity": {
            "cb_bf16_vs_lockstep_tokens": parity,
            "cb_int8_first_token": first_tok_parity,
            "sweep_uses_truncated_cache": sweep["verdict"],
        },
        "note": (
            "lockstep serves mixed lengths as sequential batch-1 runs (its "
            "only exact option); cb_bf16 must match it bit-for-bit (gated "
            "in CI). kv bytes are measured cache residency at the reduced "
            "config; 'analytic' scales the accounting to production head_dim"
        ),
    }
    _write_bench_section(path, "serving", payload)
    _write_bench_section(
        path, "sparsity_sweep",
        {"bench": "sparsity_sweep", "arch": cfg.name, "smoke": smoke, **sparsity},
    )
    _write_bench_section(
        path, "integrity",
        {"bench": "integrity", "arch": cfg.name, "smoke": smoke, **integrity},
    )
    _write_bench_section(
        path, "autopilot",
        {"bench": "autopilot", "arch": cfg.name, "smoke": smoke, **autopilot},
    )
    _write_bench_section(
        path, "tp_serving",
        {"bench": "tp_serving", "arch": cfg.name, "smoke": smoke, **tp_serving},
    )
    _write_bench_section(
        path, "paged_serving",
        {"bench": "paged_serving", "arch": cfg.name, "smoke": smoke, **paged},
    )
    _write_bench_section(
        path, "tuned_tiles",
        {"bench": "tuned_tiles", "arch": cfg.name, "smoke": smoke, **tuned},
    )
    rows = [
        ("serving/cb_int8_tok_s", payload["tok_per_s"]["cb_int8_kv"],
         f"lockstep_{payload['tok_per_s']['lockstep_per_request']}"),
        ("serving/kv_bytes_reduction_x", payload["kv_bytes"]["reduction_x"],
         f"parity_{parity}"),
        ("serving/precision_sweep_4v8_x", sweep["speedup_4_vs_8"],
         f"truncation_{sweep['verdict']}"),
        ("serving/sparsity_compact_4bit_x", sparsity["speedup_compact_vs_dense_4bit"],
         f"parity_{sparsity['parity']['sparsity_tokens_w4eff']}"),
        ("serving/integrity_detect_overhead_x", integrity["overhead_detect_vs_off_x"],
         f"faults_{integrity['parity']['fault_detection']}"
         f"_recovery_{integrity['parity']['fault_recovery_tokens']}"),
        ("serving/autopilot_p99_queue_steps", autopilot["p99_queue_steps"]["autopilot"],
         f"static_{autopilot['p99_queue_steps']['static_w8']}"
         f"_sla_{autopilot['parity']['autopilot_sla']}"
         f"_shed_{autopilot['shed']}"),
    ]
    if "skipped" in tp_serving:
        rows.append(("serving/tp4_plane_bytes_shrink_x", 0.0, "skipped"))
    else:
        rows.append((
            "serving/tp4_plane_bytes_shrink_x", tp_serving["shrink_x"]["model4"],
            f"parity_{tp_serving['parity']['tp4_tokens_vs_single_device']}",
        ))
    rows.append((
        "serving/paged_kv_shrink_x", paged["kv_shrink_x"],
        f"parity_{paged['parity']['paged_chunked_tokens_vs_dense']}"
        f"_p99_chunked_{paged['decode_iter_p99_ms']['paged_chunked']}"
        f"_mono_{paged['decode_iter_p99_ms']['paged_monolithic']}",
    ))
    rows.append((
        "serving/tuned_vs_heuristic_decode_x",
        tuned["tuned_vs_heuristic"]["decode"],
        f"prefill_{tuned['tuned_vs_heuristic']['prefill']}"
        f"_warmstart_{tuned['parity']['warm_start_zero_tune']}"
        f"_parity_{tuned['parity']['tuned_tokens_vs_heuristic']}",
    ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--precision-sweep", action="store_true",
                    help="run only the runtime-precision sweep and print it")
    ap.add_argument("--sparsity-sweep", action="store_true",
                    help="run only the occupancy-sparsity sweep and print it")
    ap.add_argument("--integrity-sweep", action="store_true",
                    help="run only the ABFT/fault-injection sweep and print it")
    ap.add_argument("--autopilot-sweep", action="store_true",
                    help="run only the SLA-autopilot overload ramp and print it")
    ap.add_argument("--tp-sweep", action="store_true",
                    help="run only the tensor-parallel serving sweep and "
                    "print it (needs 4+ devices; see XLA_FLAGS note)")
    ap.add_argument("--paged-sweep", action="store_true",
                    help="run only the paged-KV serving sweep (residency, "
                    "decode p99, parity) and print it")
    ap.add_argument("--tuned-sweep", action="store_true",
                    help="run only the autotuner sweep (tuned-vs-heuristic "
                    "throughput, warm-start zero-tune check) and print it")
    args = ap.parse_args()
    if (args.precision_sweep or args.sparsity_sweep or args.integrity_sweep
            or args.autopilot_sweep or args.tp_sweep or args.paged_sweep
            or args.tuned_sweep):
        import json as _json

        cfg = get_reduced(ARCH)
        params = init_params(cfg, jax.random.PRNGKey(0))
        fn = (precision_sweep if args.precision_sweep
              else sparsity_sweep if args.sparsity_sweep
              else integrity_sweep if args.integrity_sweep
              else autopilot_sweep if args.autopilot_sweep
              else paged_serving_sweep if args.paged_sweep
              else tuned_tiles_sweep if args.tuned_sweep
              else tp_serving_sweep)
        print(_json.dumps(fn(cfg, params, smoke=args.smoke), indent=2))
    else:
        for name, val, derived in serving_bench(args.json, smoke=args.smoke):
            print(f"{name},{val},{derived}")
