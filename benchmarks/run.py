"""Benchmark harness: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV (value column is GOPS / cycles /
microseconds as the name indicates).

    PYTHONPATH=src python -m benchmarks.run [--skip-e2e]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-e2e", action="store_true")
    ap.add_argument(
        "--json-out",
        default=None,
        help="path for the machine-readable kernel benchmark dump "
        "(default: BENCH_kernel.json, or $BENCH_KERNEL_JSON)",
    )
    args = ap.parse_args()

    from benchmarks import cycles, kernel_bench, throughput_model

    sections = [
        ("paper tables II/III/IV + fig6", throughput_model.run),
        ("cycle scaling eq6 vs eq8", cycles.run),
        (
            "bit-serial matmul kernels",
            lambda: kernel_bench.run(json_path=args.json_out),
        ),
    ]
    if not args.skip_e2e:
        from benchmarks import e2e_bench

        sections.append(("end-to-end train/serve", e2e_bench.run))

    print("name,us_per_call,derived")
    failures = 0
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for name, val, derived in fn():
                print(f"{name},{val},{derived}")
        except AssertionError as e:
            failures += 1
            print(f"# SECTION FAILED ({title}): {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
